"""Figure 3 reproduction: error rate vs training batches for the Figure-2
CNN with the paper's modified AdaGrad (β) versus unmodified AdaGrad —
demonstrating the stabilisation the paper introduced β for.

Two modes:

  * in-process (:func:`train_curve` / :func:`run`) — the CNN trained
    directly, batch by batch;
  * through the fabric (:func:`fabric_curve` / :func:`run_fabric`) —
    the same convergence reproduced end to end over the distributed
    system: gradients computed by **remote browser clients** speaking
    the v2 wire protocol against a ``TransportServer`` (per-round
    versioned weight publishes, per-leaf weight deltas), rounds closed
    through the straggler-aware K-of-N barrier (``reticket`` — exact
    math), aggregation through the fused Pallas server step.  The
    fabric trajectory must match the in-process reference computed over
    the same round shards.
"""
from __future__ import annotations

import asyncio

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import FABRIC_CNN, FIG2_CNN
from repro.data import clustered_images
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def train_curve(beta: float, *, batches: int = 60, lr: float = 0.02,
                eval_every: int = 10):
    ccfg = FIG2_CNN
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    opt = adagrad(lr, beta=beta)
    opt_state = opt.init(params)
    images, labels = clustered_images(2048, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=0)
    test_x, test_y = clustered_images(256, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=7)
    test_x, test_y = jnp.asarray(test_x), jnp.asarray(test_y)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            return cnn.nll_loss(cnn.forward(p, ccfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    @jax.jit
    def err(params):
        return cnn.error_rate(cnn.forward(params, ccfg, test_x), test_y)

    bs = ccfg.batch_size
    curve = []
    for i in range(batches):
        j = (i * bs) % (len(images) - bs)
        params, opt_state, loss = step(
            params, opt_state, jnp.asarray(images[j:j + bs]),
            jnp.asarray(labels[j:j + bs]))
        if (i + 1) % eval_every == 0:
            curve.append((i + 1, float(err(params)), float(loss)))
    return curve


def run(*, batches: int = 60):
    out = []
    for beta, name in [(1.0, "modified adagrad (beta=1)"),
                       (1e-8, "plain adagrad (beta~0)")]:
        curve = train_curve(beta, batches=batches)
        for step_i, e, loss in curve:
            out.append({"optimizer": name, "batch": step_i,
                        "error_rate": round(e, 4), "loss": round(loss, 4)})
    return out


# ---------------------------------------------------------------------------
# The same convergence, end to end through the fabric
# ---------------------------------------------------------------------------

FABRIC_ROWS = 128      # clustered-images rows, sharded per round
FABRIC_SHARDS = 4
FABRIC_LR = 0.05


def _fabric_plan():
    bounds = np.linspace(0, FABRIC_ROWS, FABRIC_SHARDS + 1).astype(int)
    args = [(int(lo), int(hi)) for lo, hi in zip(bounds[:-1], bounds[1:])]
    return args, [float(hi - lo) for lo, hi in args]


def reference_curve(rounds: int, *, beta: float = 1.0) -> list[float]:
    """The fabric run's in-process twin: the same ``CnnGradShard`` task
    over the same round shards, aggregated by the tree_map reference
    server step — what the distributed trajectory must reproduce."""
    from repro.train_fabric import TreeServerStep

    task = cnn.CnnGradShard(FABRIC_CNN, n_rows=FABRIC_ROWS)
    opt = adagrad(FABRIC_LR, beta=beta)
    params = jax.device_get(
        values_tree(cnn.init_cnn(jax.random.PRNGKey(0), FABRIC_CNN)))
    opt_state = opt.init(params)
    step = TreeServerStep(opt)
    args, work = _fabric_plan()
    losses = []
    for t in range(rounds):
        outs = [task(a, {"weights": {"round": t, "params": params}})
                for a in args]
        params, opt_state = step.step([o["grad"] for o in outs], work,
                                      params, opt_state)
        losses.append(sum(o["loss"] * w for o, w in zip(outs, work))
                      / sum(work))
    return losses


async def _fabric_train(rounds: int, *, beta: float, n_clients: int = 3
                        ) -> dict:
    """Fig-3-style rounds through the FULL fabric: remote clients over
    the v2 wire protocol, K-of-N reticket barrier, versioned per-round
    weight publishes (per-leaf deltas), fused server step."""
    from repro.core.distributor import ClientProfile, TaskDef
    from repro.core.federation import FederatedDistributor
    from repro.core.split_parallel import TrainState
    from repro.core.transport import TransportServer, spawn_remote_clients
    from repro.train_fabric import (FederatedTrainer, FederatedTrainingLoop,
                                    FusedServerStep)

    fed = FederatedDistributor(2, n_shards=4, timeout=20.0,
                               redistribute_min=0.02,
                               watchdog_interval=0.01, grace=2.0,
                               project_name="Fig3Fabric")
    fed.register_task(TaskDef(
        "cnn_grad_shard", cnn.CnnGradShard(FABRIC_CNN, n_rows=FABRIC_ROWS),
        static_files=("weights",)))
    server = TransportServer(fed)
    host, port = await server.start()
    clients, tasks = spawn_remote_clients(
        (host, port),
        [ClientProfile(name=f"r{i}", speed=500.0)
         for i in range(n_clients)],
        reconnect_delay=0.02)
    opt = adagrad(FABRIC_LR, beta=beta)
    params = jax.device_get(
        values_tree(cnn.init_cnn(jax.random.PRNGKey(0), FABRIC_CNN)))
    state = TrainState(params=params, head={}, head_stale={},
                       opt_state=opt.init(params), head_opt_state={},
                       prev_features=(), prev_labels=(), prev_mask=(),
                       step=np.zeros((), np.int32))
    trainer = FederatedTrainer(fed, task_name="cnn_grad_shard",
                               barrier_k=0.75,
                               straggler_policy="reticket", timeout=30.0)
    loop = FederatedTrainingLoop(
        trainer, opt, state,
        server_step=FusedServerStep(opt, lr=FABRIC_LR, beta=beta))
    args, work = _fabric_plan()
    delta_leaves = []
    async with trainer:
        for _ in range(rounds):
            res = await loop.run_round(args, work)
            d = res.publish_deltas.get("weights")
            if d is not None:
                delta_leaves.append((d["changed"], d["leaves"]))
    await asyncio.gather(*tasks)
    await server.stop()
    await fed.shutdown()
    return {"losses": loop.losses,
            "stale_executions": loop.stale_executions,
            "reticketed": trainer.reticketed_total,
            "publish_deltas": delta_leaves}


def run_fabric(*, rounds: int = 6) -> dict:
    """Convergence through the full fabric vs its in-process twin."""
    fab = asyncio.run(_fabric_train(rounds, beta=1.0))
    ref = reference_curve(rounds)
    delta = max(abs(a - b) for a, b in zip(fab["losses"], ref))
    out = {"rounds": rounds, "model": FABRIC_CNN.name,
           "loss_first": fab["losses"][0], "loss_final": fab["losses"][-1],
           "max_loss_delta_vs_in_process": float(delta),
           "stale_executions": fab["stale_executions"],
           "wire_delta_publishes": len(fab["publish_deltas"])}
    assert out["stale_executions"] == 0, out
    assert delta < 1e-6, \
        f"fabric trajectory must match the in-process twin: {out}"
    assert fab["losses"][-1] < fab["losses"][0], \
        f"the Fig-3 curve must converge through the fabric: {out}"
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
    print(run_fabric())
