"""Browser-scale churn benchmark: 10k remote clients, 20%/round churn.

Discrete-event simulation (virtual clock — deterministic, runs in
seconds) of the transport's browser-scale machinery at populations no
socket test can reach.  The population comes from
:mod:`repro.core.profiles` (GPU/CPU tiers, heavy-tailed latencies,
per-round tab-close hazards scaled to the target churn); ticket
accounting is the REAL :class:`repro.core.shards.ShardedTicketQueue`
behind per-member serialized service stations, exactly as in
``federation_throughput.py``.  On top of that base the sim models the
three churn mechanisms of `docs/PROTOCOL.md`:

  * **admission control** — at most ``CONNS_PER_MEMBER`` connected
    clients per member; everyone else is refused (``busy``) and re-dials
    with the client's real capped-exponential jittered backoff
    (:func:`repro.core.transport.reconnect_backoff` — the sim imports
    the production schedule, not a copy);
  * **heartbeat eviction** — a tab that closes mid-lease goes silent;
    the server notices after ``HEARTBEAT_TIMEOUT`` virtual seconds and
    force-releases its leases (the watchdog is parked at a prohibitive
    grace so eviction is the only recovery path);
  * **round churn** — every round, each client dies with its profile's
    tab-close hazard (population mean = the target churn rate) and is
    replaced by a fresh device, like new visitors opening the page.

Rounds are driven to completion and audited for the acceptance bars:
**zero stalled rounds** (no open round goes ``STALL_AFTER`` virtual
seconds without a completion), **zero lost tickets**, **zero duplicate
completions** (exactly-once accepts), churned 4-member throughput
**>= 0.9x** the no-churn ceiling, and 4-member-over-1-member speedup.
``benchmarks/run.py --only churn`` re-runs this and writes
``BENCH_churn.json``; assertions run BEFORE the file is written.

``--flight-dump FILE`` additionally arms a flight recorder: a
ring-buffered :class:`repro.obs.Tracer` rides the churned cell
(``transport.busy`` / ``transport.evict`` instants at the sim's
admission refusals and eviction sweeps) with a ``dump_on`` trigger on
the first eviction, so the run writes a bounded Perfetto file showing
the lead-up to the failure — the same mechanism production code arms on
``distributor.stall``.  CI runs the smoke cell with it and uploads the
dump as an artifact.

Usage:
  PYTHONPATH=src python benchmarks/churn_scale.py [--json out.json]
                                                  [--smoke] [--seed N]
                                                  [--flight-dump FILE]
"""
from __future__ import annotations

import argparse
import heapq
import itertools
import json
import random
import sys

sys.path.insert(0, "src")

from repro.core.distributor import AdaptiveSizer
from repro.core.federation import grant_has_foreign_tickets
from repro.core.profiles import draw_fleet, fleet_summary, scale_hazard
from repro.core.shards import ShardedTicketQueue
from repro.core.transport import reconnect_backoff

RTT = 0.05               # client <-> member round-trip (virtual s)
SERVICE = 0.02           # member service time per lease/submit request
POPULATION = 10_000
SMOKE_POPULATION = 1_000
CHURN_PER_ROUND = 0.2    # mean tab-close probability per round
ROUNDS = 2
TICKETS_PER_MEMBER_ROUND = 1500   # sized to capacity, not population:
#                                   admission caps the working set, so
#                                   throughput is station-bound and a
#                                   round should run long enough (~30
#                                   virtual s) to amortize its tail
CONNS_PER_MEMBER = 64    # admission cap
HEARTBEAT_TIMEOUT = 0.5  # silence -> eviction (virtual s)
STALL_AFTER = 5.0        # no completion this long while open = stall
ROUND_HARD_CAP = 300.0   # virtual s; a round this long is lost, not hung
RECONNECT_DELAY = 0.5    # backoff base for refused/failed dials
BACKOFF_CAP = 8.0
GRACE = 1000.0           # watchdog effectively off: eviction must do it
REDISTRIBUTE_MIN = 3.0   # straggler re-lease (> heartbeat timeout)
MAX_LATENCY = 1.0        # cap the Pareto tail: browsers time out too


class SimClock:
    """Injectable virtual clock (docs/ARCHITECTURE.md §Injectable clock)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class _Client:
    __slots__ = ("name", "speed", "latency", "hazard", "alive", "member",
                 "attempts", "leases")

    def __init__(self, draw):
        self.name = draw.name
        self.speed = draw.speed
        self.latency = min(draw.latency, MAX_LATENCY)
        self.hazard = draw.tab_close_hazard
        self.alive = True
        self.member = None       # admitted endpoint, or None (parked)
        self.attempts = 0        # consecutive refused/failed dials
        self.leases = {}         # lease_id -> batch (granted, unsubmitted)


def simulate(population: int, n_members: int, *, rounds: int = ROUNDS,
             tickets_per_round: int | None = None,
             churn: float = CHURN_PER_ROUND, seed: int = 0,
             tracer=None) -> dict:
    """One cell: ``rounds`` rounds of ``tickets_per_round`` tickets over a
    churning population.  Returns throughput + the audit counters."""
    if tickets_per_round is None:
        tickets_per_round = TICKETS_PER_MEMBER_ROUND * n_members
    clock = SimClock()
    n_shards = max(2 * n_members, 2)
    q = ShardedTicketQueue(n_shards, timeout=1e6,
                           redistribute_min=REDISTRIBUTE_MIN, clock=clock)
    sizer = AdaptiveSizer(target_lease_time=0.5, max_size=8)
    home = {m: [q.shards[j] for j in range(n_shards) if j % n_members == m]
            for m in range(n_members)}

    rng = random.Random(seed ^ 0xC0FFEE)
    fleet = scale_hazard(draw_fleet(population, seed=seed), churn)
    clients = {d.name: _Client(d) for d in fleet}
    joined = itertools.count(population)   # names for replacement devices

    conns = [0] * n_members
    busy = [0.0] * n_members
    seq = itertools.count()
    events: list = []

    stats = {"busy_refusals": 0, "evictions": 0, "evicted_leases": 0,
             "deaths": 0, "steals": 0, "accepted_total": 0,
             "dup_submits_dropped": 0}

    def service(member: int, t: float) -> float:
        start = max(t, busy[member])
        busy[member] = start + SERVICE
        return busy[member]

    def push(t, kind, name, payload=None):
        heapq.heappush(events, (t, next(seq), kind, name, payload))

    for name in clients:
        push(rng.random() * 2.0, "join", name)

    def apply_churn(t0: float, span: float):
        """Each client dies with its own hazard at a uniform time inside
        the round's opening ``span``; a fresh device replaces it."""
        for name in list(clients):
            c = clients[name]
            if not c.alive or rng.random() >= c.hazard:
                continue
            died_at = t0 + rng.random() * span
            push(died_at, "death", name)
            new = draw_fleet(1, seed=seed + next(joined))[0]
            replacement = _Client(new)
            replacement.name = f"j{next(joined)}-{new.name}"
            clients[replacement.name] = replacement
            push(died_at + rng.random(), "join", replacement.name)

    executed_before = 0
    round_records = []
    stalled_rounds = 0
    lost = 0
    total_added = 0

    for r in range(rounds):
        t0 = clock.t
        tids = q.add_many(f"round{r}", list(range(tickets_per_round)),
                          work=1.0)
        total_added += len(tids)
        apply_churn(t0, span=2.0)
        target = executed_before + tickets_per_round
        last_progress = t0
        stalled = False

        while events:
            t, _, kind, name, payload = heapq.heappop(events)
            clock.t = t
            # accepted_total == executed: the queue accepts each ticket's
            # result exactly once (audited at the end via snapshot())
            done = stats["accepted_total"]
            if done >= target:
                break
            if done > executed_before:
                executed_before = done
                last_progress = t
            elif not stalled and t - last_progress > STALL_AFTER:
                stalled = True
                stalled_rounds += 1
            if t - t0 > ROUND_HARD_CAP:
                break

            c = clients.get(name) if name else None

            if kind == "death":
                stats["deaths"] += 1
                c.alive = False
                if c.member is not None:
                    # silent tab: the server notices at the heartbeat
                    # deadline and evicts (slot freed, leases released)
                    push(t + HEARTBEAT_TIMEOUT, "evict", name)
                continue
            if kind == "evict":
                stats["evictions"] += 1
                if tracer is not None:
                    tracer.instant("transport.evict", track="wire",
                                   cat="wire", ts=t,
                                   args={"client": name,
                                         "leases": len(c.leases)})
                conns[c.member] -= 1
                c.member = None
                for lease_id in list(c.leases):
                    del c.leases[lease_id]
                    stats["evicted_leases"] += q.release(
                        lease_id, client_failed=True)
                continue
            if c is None or not c.alive:
                continue                    # event for a dead client

            if kind == "join":
                m = min(range(n_members), key=lambda i: conns[i])
                if conns[m] >= CONNS_PER_MEMBER:
                    stats["busy_refusals"] += 1
                    if tracer is not None:
                        tracer.instant("transport.busy", track="wire",
                                       cat="wire", ts=t,
                                       args={"client": name,
                                             "attempts": c.attempts + 1})
                    c.attempts += 1
                    push(t + reconnect_backoff(
                        c.attempts, base=RECONNECT_DELAY, cap=BACKOFF_CAP,
                        rand=rng.random), "join", name)
                    continue
                conns[m] += 1
                c.member = m
                c.attempts = 0
                push(service(m, t), "lease", name)
            elif kind == "lease":
                if c.member is None:
                    continue                # evicted while parked in heap
                m = c.member
                n = sizer.lease_size(q.stats.get(name))
                batch = q.lease(name, n, shards=home[m])
                if batch is None and len(home[m]) < n_shards:
                    batch = q.lease(name, n)
                    if batch is not None and grant_has_foreign_tickets(
                            batch, home[m]):
                        stats["steals"] += 1
                if batch is None:
                    # dry: the real server parks the request; poll cheaply
                    push(t + 0.25, "lease", name)
                    continue
                c.leases[batch.lease_id] = batch
                finish = t + RTT + c.latency + batch.work / c.speed
                push(finish, "finish", name, batch)
            elif kind == "finish":
                if c.member is None or payload.lease_id not in c.leases:
                    continue                # evicted mid-compute
                push(service(c.member, t), "submitted", name, payload)
            elif kind == "submitted":
                batch = payload
                if c.member is None or batch.lease_id not in c.leases:
                    continue                # evicted while submit in flight
                del c.leases[batch.lease_id]
                accepted = q.submit_batch(
                    batch.lease_id,
                    {tid: tid for tid in batch.ticket_ids}, name)
                stats["accepted_total"] += accepted
                stats["dup_submits_dropped"] += \
                    len(batch.ticket_ids) - accepted
                push(t, "lease", name)

        snap = q.snapshot()
        executed_before = snap["executed"]
        round_lost = target - executed_before
        if round_lost > 0:
            lost += round_lost
        round_records.append({
            "round": r, "duration_s": round(clock.t - t0, 3),
            "completed": tickets_per_round - max(round_lost, 0),
            "stalled": stalled,
        })

    snap = q.snapshot()
    makespan = max(clock.t, 1e-9)
    duplicate_completions = stats["accepted_total"] - snap["executed"]
    return {
        "population": population,
        "members": n_members,
        "rounds": rounds,
        "tickets_per_round": tickets_per_round,
        "churn_per_round": churn,
        "makespan_s": round(makespan, 3),
        "throughput_tps": round(snap["executed"] / makespan, 2),
        "completed": snap["executed"],
        "total": total_added,
        "lost_tickets": lost,
        "duplicate_completions": duplicate_completions,
        "stalled_rounds": stalled_rounds,
        "round_records": round_records,
        **stats,
        "redistributions": snap["redistributions"],
        "lease_releases": snap["lease_releases"],
    }


def run_sweep(*, population: int = POPULATION, seed: int = 0,
              tracer=None) -> dict:
    """The benchmark cells: the churned 10k run, its no-churn ceiling,
    and a 1-member cell for the scaling headline.  ``tracer`` (if any)
    rides the churned cell only — that is the one with failures worth a
    flight-recorder dump."""
    churned = simulate(population, 4, churn=CHURN_PER_ROUND, seed=seed,
                       tracer=tracer)
    ceiling = simulate(population, 4, churn=0.0, seed=seed)
    single = simulate(population, 1, rounds=1, churn=CHURN_PER_ROUND,
                      seed=seed)
    ratio = round(churned["throughput_tps"]
                  / max(ceiling["throughput_tps"], 1e-9), 3)
    speedup = round(churned["throughput_tps"]
                    / max(single["throughput_tps"], 1e-9), 2)
    return {
        "churned": churned,
        "ceiling": ceiling,
        "single_member": single,
        "throughput_ratio_vs_ceiling": ratio,
        "speedup_4v1": speedup,
        "fleet": fleet_summary(scale_hazard(
            draw_fleet(population, seed=seed), CHURN_PER_ROUND)),
        "model": {"rtt_s": RTT, "service_s": SERVICE,
                  "conns_per_member": CONNS_PER_MEMBER,
                  "heartbeat_timeout_s": HEARTBEAT_TIMEOUT,
                  "stall_after_s": STALL_AFTER, "grace": GRACE,
                  "redistribute_min_s": REDISTRIBUTE_MIN,
                  "seed": seed},
    }


def check(results: dict) -> None:
    """The acceptance bars (run BEFORE any JSON is written)."""
    for cell in ("churned", "ceiling", "single_member"):
        m = results[cell]
        assert m["stalled_rounds"] == 0, (cell, m["round_records"])
        assert m["lost_tickets"] == 0, (cell, m)
        assert m["completed"] == m["total"], (cell, m)
        assert m["duplicate_completions"] == 0, (cell, m)
    ch = results["churned"]
    assert ch["evictions"] > 0, \
        "churn must exercise the eviction path (watchdog is parked)"
    assert ch["busy_refusals"] > 0, \
        "the population must exceed the admission cap"
    assert results["throughput_ratio_vs_ceiling"] >= 0.9, results
    assert results["speedup_4v1"] >= 2.0, results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    ap.add_argument("--smoke", action="store_true",
                    help=f"reduced population ({SMOKE_POPULATION}) for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--flight-dump", default=None, metavar="FILE",
                    help="arm a ring-buffered flight recorder on the "
                         "churned cell; the first eviction triggers a "
                         "bounded Perfetto dump to FILE")
    args = ap.parse_args()
    population = SMOKE_POPULATION if args.smoke else POPULATION
    tracer = None
    if args.flight_dump:
        from repro.obs import Tracer
        tracer = Tracer(max_events=4096)
        tracer.dump_on("transport.evict", args.flight_dump)
    results = run_sweep(population=population, seed=args.seed,
                        tracer=tracer)

    hdr = f"{'cell':<15}{'pop':>7}{'mem':>4}{'tput(t/s)':>11}" \
          f"{'stalls':>7}{'lost':>6}{'dup':>5}{'evict':>7}{'busy':>7}"
    print(hdr)
    print("-" * len(hdr))
    for cell in ("churned", "ceiling", "single_member"):
        m = results[cell]
        print(f"{cell:<15}{m['population']:>7}{m['members']:>4}"
              f"{m['throughput_tps']:>11.1f}{m['stalled_rounds']:>7}"
              f"{m['lost_tickets']:>6}{m['duplicate_completions']:>5}"
              f"{m['evictions']:>7}{m['busy_refusals']:>7}")
    print(f"\nchurned throughput holds "
          f"{results['throughput_ratio_vs_ceiling']:.3f}x the no-churn "
          f"ceiling; 4-member speedup {results['speedup_4v1']:.2f}x")
    check(results)

    if tracer is not None:
        # check() just proved evictions > 0, so the trigger MUST have
        # fired — a missing dump means the recorder itself regressed
        assert tracer.dumps_written, \
            "evictions happened but no flight dump was written"
        print(f"flight recorder dumped {tracer.dumps_written[0]} "
              f"({len(tracer.events())} buffered events, "
              f"{tracer.events_dropped} evicted from the ring)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
