"""Transport-overhead benchmark: serialized wire rounds vs in-process.

Two runs of the identical workload (same distributor, sizer, client count
and speeds, same ticket mix):

  * ``inprocess`` — ``AsyncBrowserClient`` tasks sharing the event loop
    with the distributor, communicating by method calls (the pre-transport
    federation's only mode);
  * ``transport`` — every client is a ``RemoteBrowserClient`` on the far
    side of a loopback socket speaking the length-prefixed JSON protocol
    (docs/PROTOCOL.md): every lease, submit, and asset fetch is a framed,
    pickled round-trip.

The headline number is **round-throughput ratio** (transport tickets/s ÷
in-process tickets/s); the acceptance bar is ≥ 0.5x.  The wire ledger
(frames and bytes per direction — ``down`` = server→client, ``up`` =
client→server) quantifies what a round actually costs in serialization.
A third phase re-runs the PR 3 **re-register storm** with every client
remote and asserts **zero stale serves** — cache coherence must survive
the serialization boundary.

The **weight-rounds** phase measures what protocol v2 was built for: a
paper-sized CNN ``TrainState`` (the Fig. 2 network — conv 5×5×{16,20,20}
+ FC 320→10 — in bfloat16) re-published every round with only the FC
head changing (a frozen-backbone fine-tune, ~14% of the parameters).
The identical workload runs against a v1-only server (JSON frames,
pickle+base64 payloads, full re-download per round) and a v2 server
(binary frames, changed-leaves deltas); the acceptance bar is
**down-bytes/round ratio > 5x** with zero stale serves on both.  With
``--baseline`` the v2 bytes/round are additionally gated against a
recorded baseline ×1.2 (the CI regression check).

Unlike the virtual-clock benchmarks, this one runs real sockets, so it
uses wall-clock time: each cell is the median of ``REPS`` repetitions
(the byte ledgers are deterministic and measured once).

Usage:
  PYTHONPATH=src python benchmarks/transport_overhead.py \
      [--json out.json] [--baseline benchmarks/baselines/transport_baseline.json] \
      [--update-baseline]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import statistics
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, TaskDef)
from repro.core.transport import TransportServer, spawn_remote_clients

try:
    import ml_dtypes
    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:    # pragma: no cover - jax always ships ml_dtypes
    BF16 = np.dtype(np.float16)

N_TICKETS = 400
N_CLIENTS = 4
SPEED = 800.0          # work units/s -> 1.25 ms simulated compute/ticket
REPS = 3
STORM_ROUNDS = 8
STORM_TICKETS = 16
WEIGHT_ROUNDS = 8      # weight-rounds phase: 1 cold + this many deltas
BASELINE_SLACK = 1.2   # --baseline gate: fail past recorded bytes × this


def _square(x, static):
    return x * x


def _read_weights(x, static):
    return (x, static["weights"])


def _weights_probe(x, static):
    """Touch every leaf of this round's weights, return tiny results (so
    the UP direction stays small and DOWN isolates the publish cost)."""
    w = static["weights"]
    checksum = float(sum(np.asarray(v, np.float32).sum()
                         for layer in w["params"].values()
                         for v in layer.values()))
    return (w["round"], checksum)


def _fig2_cnn_params(rng):
    """The paper's Fig. 2 CNN for 32×32×3 inputs, as a bfloat16 pytree:
    three 5×5 conv layers (16/20/20 maps, 2×2 pooling) and a 320→10 FC
    head — ~22.5k parameters, ~45 KB raw in bf16."""
    def w(*shape):
        return rng.standard_normal(shape).astype(BF16)
    return {
        "conv1": {"w": w(5, 5, 3, 16), "b": w(16)},
        "conv2": {"w": w(5, 5, 16, 20), "b": w(20)},
        "conv3": {"w": w(5, 5, 20, 20), "b": w(20)},
        "fc": {"w": w(320, 10), "b": w(10)},
    }


def _profiles():
    return [ClientProfile(name=f"c{i}", speed=SPEED)
            for i in range(N_CLIENTS)]


def _dist(**kw):
    return AsyncDistributor(
        timeout=30.0, redistribute_min=0.05,
        sizer=AdaptiveSizer(target_lease_time=0.05, max_size=32),
        watchdog_interval=0.02, grace=4.0, **kw)


async def _run_inprocess() -> float:
    d = _dist()
    d.register_task(TaskDef("sq", _square))
    tids = d.add_work("sq", list(range(N_TICKETS)))
    d.spawn_clients(_profiles())
    t0 = time.perf_counter()
    ok = await d.run_until_done(timeout=120.0)
    elapsed = time.perf_counter() - t0
    assert ok, d.console()
    assert len(d.queue.results_for(tids)) == N_TICKETS
    return elapsed


async def _run_transport() -> tuple[float, dict]:
    d = _dist()
    d.register_task(TaskDef("sq", _square))
    tids = d.add_work("sq", list(range(N_TICKETS)))
    server = TransportServer(d)
    addr = await server.start()
    t0 = time.perf_counter()
    clients, tasks = spawn_remote_clients(addr, _profiles())
    ok = await d.run_until_done(timeout=120.0)
    elapsed = time.perf_counter() - t0
    assert ok, d.console()
    assert len(d.queue.results_for(tids)) == N_TICKETS
    await asyncio.gather(*tasks)
    wire = server.stats()
    await server.stop()
    return elapsed, wire


async def _run_storm() -> dict:
    """The PR 3 re-register storm with every client remote: weights are
    re-published each round; a ticket observing any other round's weights
    is a stale serve.  The bar is zero."""
    d = _dist(keep_alive=True)
    d.add_static("weights", -1)
    d.register_task(TaskDef("rw", _read_weights, static_files=("weights",)))
    server = TransportServer(d)
    addr = await server.start()
    clients, tasks = spawn_remote_clients(addr, _profiles())
    stale = total = 0
    for rnd in range(STORM_ROUNDS):
        d.add_static("weights", rnd)
        tids = d.add_work("rw", list(range(STORM_TICKETS)))
        deadline = time.monotonic() + 60.0
        while True:
            wake = d._wake_event()
            out = d.queue.results_for(tids)
            if out is not None:
                break
            assert time.monotonic() < deadline, d.console()
            await d._wait_on(wake, 0.05)
        for _, w in out:
            total += 1
            stale += (w != rnd)
        d.queue.prune(tids)
    for c in clients:
        await c.stop()
    await asyncio.gather(*tasks, return_exceptions=True)
    await d.shutdown()
    await server.stop()
    return {"rounds": STORM_ROUNDS, "tickets": total, "stale_serves": stale,
            "revalidations": sum(c.revalidations for c in clients),
            "push_invalidations": sum(c.push_invalidations
                                      for c in clients)}


async def _run_weight_rounds(max_proto: int) -> dict:
    """The frozen-backbone fine-tune shape on the wire: publish the full
    CNN state once, then re-publish every round with only the FC head
    changed.  One client (byte ledgers stay deterministic), speed high
    enough that serialization dominates.  Returns per-direction bytes per
    steady-state round (the cold first round is excluded — it is a full
    download on every protocol)."""
    d = _dist(keep_alive=True)
    rng = np.random.default_rng(0)
    params = _fig2_cnn_params(rng)
    d.add_static("weights", {"round": -1, "params": params})
    d.register_task(TaskDef("wp", _weights_probe,
                            static_files=("weights",)))
    server = TransportServer(d, max_proto=max_proto)
    addr = await server.start()
    clients, tasks = spawn_remote_clients(
        addr, [ClientProfile(name="c0", speed=SPEED)])
    stale = total = 0
    marks = []                       # (bytes_down, bytes_up) after each round
    for rnd in range(WEIGHT_ROUNDS + 1):
        # frozen backbone: only the FC head (and the round tag) change
        params = {**params,
                  "fc": {"w": rng.standard_normal((320, 10)).astype(BF16),
                         "b": rng.standard_normal(10).astype(BF16)}}
        d.add_static("weights", {"round": rnd, "params": params})
        tids = d.add_work("wp", list(range(4)))
        deadline = time.monotonic() + 60.0
        while True:
            wake = d._wake_event()
            out = d.queue.results_for(tids)
            if out is not None:
                break
            assert time.monotonic() < deadline, d.console()
            await d._wait_on(wake, 0.05)
        for seen, _ in out:
            total += 1
            stale += (seen != rnd)
        d.queue.prune(tids)
        marks.append((server.bytes_out, server.bytes_in))
    for c in clients:
        await c.stop()
    await asyncio.gather(*tasks, return_exceptions=True)
    await d.shutdown()
    await server.stop()
    assert stale == 0, f"{stale}/{total} stale serves at proto {max_proto}"
    # steady state: rounds 1..N (round 0 pays the cold full download)
    down = (marks[-1][0] - marks[0][0]) / WEIGHT_ROUNDS
    up = (marks[-1][1] - marks[0][1]) / WEIGHT_ROUNDS
    return {"proto": clients[0].proto,
            "rounds": WEIGHT_ROUNDS,
            "bytes_down_per_round": round(down, 1),
            "bytes_up_per_round": round(up, 1),
            "deltas_applied": clients[0].deltas_applied,
            "full_downloads": int(d.download_count["weights"]),
            "delta_downloads": int(d.delta_count["weights"]),
            "stale_serves": stale}


def _weight_rounds_cell() -> dict:
    v1 = asyncio.run(_run_weight_rounds(max_proto=1))
    v2 = asyncio.run(_run_weight_rounds(max_proto=2))
    ratio_down = v1["bytes_down_per_round"] / v2["bytes_down_per_round"]
    ratio_up = v1["bytes_up_per_round"] / v2["bytes_up_per_round"]
    return {"v1": v1, "v2": v2,
            "ratio_down": round(ratio_down, 2),
            "ratio_up": round(ratio_up, 2)}


def run_sweep() -> dict:
    """Run all cells; returns the machine-readable results dict
    (``benchmarks/run.py`` writes it as BENCH_transport.json)."""
    inproc = [asyncio.run(_run_inprocess()) for _ in range(REPS)]
    trans = []
    wire = None
    for _ in range(REPS):
        elapsed, wire = asyncio.run(_run_transport())
        trans.append(elapsed)
    t_in = statistics.median(inproc)
    t_tr = statistics.median(trans)
    thr_in = N_TICKETS / t_in
    thr_tr = N_TICKETS / t_tr
    storm = asyncio.run(_run_storm())
    return {
        "workload": {"tickets": N_TICKETS, "clients": N_CLIENTS,
                     "speed": SPEED, "reps": REPS},
        "inprocess": {"makespan_s": round(t_in, 4),
                      "tickets_per_s": round(thr_in, 1)},
        "transport": {"makespan_s": round(t_tr, 4),
                      "tickets_per_s": round(thr_tr, 1),
                      "frames": wire["frames_in"] + wire["frames_out"],
                      "wire_bytes": wire["bytes_in"] + wire["bytes_out"],
                      "bytes_up": wire["bytes_in"],
                      "bytes_down": wire["bytes_out"],
                      "bytes_up_per_ticket": round(
                          wire["bytes_in"] / N_TICKETS, 1),
                      "bytes_down_per_ticket": round(
                          wire["bytes_out"] / N_TICKETS, 1),
                      "bytes_per_ticket": round(
                          (wire["bytes_in"] + wire["bytes_out"])
                          / N_TICKETS, 1)},
        "throughput_ratio": round(thr_tr / thr_in, 3),
        "storm": storm,
        "weight_rounds": _weight_rounds_cell(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results to this path")
    ap.add_argument("--baseline", default=None,
                    help="gate v2 weight-round bytes against this recorded "
                         f"baseline × {BASELINE_SLACK} (CI regression check)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline with the measured v2 bytes")
    args = ap.parse_args()
    results = run_sweep()
    print(f"{'cell':<12} {'makespan':>10} {'tickets/s':>10}")
    for cell in ("inprocess", "transport"):
        r = results[cell]
        print(f"{cell:<12} {r['makespan_s']:>9.3f}s "
              f"{r['tickets_per_s']:>10.1f}")
    tr = results["transport"]
    print(f"wire: {tr['frames']} frames, {tr['wire_bytes']} bytes "
          f"(up {tr['bytes_up_per_ticket']} + "
          f"down {tr['bytes_down_per_ticket']} bytes/ticket)")
    print(f"throughput ratio (transport/in-process): "
          f"{results['throughput_ratio']}x")
    s = results["storm"]
    print(f"storm over the wire: {s['stale_serves']}/{s['tickets']} stale "
          f"({s['revalidations']} revalidations, "
          f"{s['push_invalidations']} push invalidations)")
    wr = results["weight_rounds"]
    print(f"weight rounds (Fig.2 CNN, bf16, FC-only updates):")
    for proto in ("v1", "v2"):
        r = wr[proto]
        print(f"  {proto}: down {r['bytes_down_per_round']:>9.1f} B/round  "
              f"up {r['bytes_up_per_round']:>7.1f} B/round  "
              f"(deltas {r['delta_downloads']}, "
              f"full downloads {r['full_downloads']})")
    print(f"  down-bytes ratio v1/v2: {wr['ratio_down']}x "
          f"(up: {wr['ratio_up']}x)")
    # acceptance bars: coherence survives serialization, the wire costs
    # at most half the in-process round throughput, and v2 deltas cut the
    # publish-direction bytes by more than 5x on the paper-CNN workload
    assert s["stale_serves"] == 0, s
    assert results["throughput_ratio"] >= 0.5, results
    assert wr["v2"]["deltas_applied"] >= WEIGHT_ROUNDS - 1, wr
    assert wr["ratio_down"] > 5.0, wr
    if args.baseline:
        gate_against_baseline(wr, args.baseline,
                              update=args.update_baseline)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


def gate_against_baseline(wr: dict, path: str, *, update: bool = False):
    """Fail when the measured v2 bytes/round regress above the recorded
    baseline × BASELINE_SLACK; ``update=True`` rewrites the record."""
    measured = {"v2_bytes_down_per_round": wr["v2"]["bytes_down_per_round"],
                "v2_bytes_up_per_round": wr["v2"]["bytes_up_per_round"],
                "ratio_down": wr["ratio_down"]}
    if update:
        with open(path, "w") as f:
            json.dump(measured, f, indent=2)
            f.write("\n")
        print(f"baseline updated: {path}")
        return
    with open(path) as f:
        baseline = json.load(f)
    for key in ("v2_bytes_down_per_round", "v2_bytes_up_per_round"):
        cap = baseline[key] * BASELINE_SLACK
        assert measured[key] <= cap, (
            f"{key} regressed: {measured[key]} > {baseline[key]} x "
            f"{BASELINE_SLACK} = {cap:.1f}")
    print(f"baseline ok: {path} "
          f"(down {measured['v2_bytes_down_per_round']} <= "
          f"{baseline['v2_bytes_down_per_round']} x {BASELINE_SLACK})")


if __name__ == "__main__":
    main()
