"""Transport-overhead benchmark: serialized wire rounds vs in-process.

Two runs of the identical workload (same distributor, sizer, client count
and speeds, same ticket mix):

  * ``inprocess`` — ``AsyncBrowserClient`` tasks sharing the event loop
    with the distributor, communicating by method calls (the pre-transport
    federation's only mode);
  * ``transport`` — every client is a ``RemoteBrowserClient`` on the far
    side of a loopback socket speaking the length-prefixed JSON protocol
    (docs/PROTOCOL.md): every lease, submit, and asset fetch is a framed,
    pickled round-trip.

The headline number is **round-throughput ratio** (transport tickets/s ÷
in-process tickets/s); the acceptance bar is ≥ 0.5x.  The wire ledger
(frames and bytes per ticket) quantifies what a round actually costs in
serialization.  A third phase re-runs the PR 3 **re-register storm** with
every client remote and asserts **zero stale serves** — cache coherence
must survive the serialization boundary.

Unlike the virtual-clock benchmarks, this one runs real sockets, so it
uses wall-clock time: each cell is the median of ``REPS`` repetitions.

Usage:
  PYTHONPATH=src python benchmarks/transport_overhead.py [--json out.json]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import statistics
import sys
import time

sys.path.insert(0, "src")

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, TaskDef)
from repro.core.transport import TransportServer, spawn_remote_clients

N_TICKETS = 400
N_CLIENTS = 4
SPEED = 800.0          # work units/s -> 1.25 ms simulated compute/ticket
REPS = 3
STORM_ROUNDS = 8
STORM_TICKETS = 16


def _square(x, static):
    return x * x


def _read_weights(x, static):
    return (x, static["weights"])


def _profiles():
    return [ClientProfile(name=f"c{i}", speed=SPEED)
            for i in range(N_CLIENTS)]


def _dist(**kw):
    return AsyncDistributor(
        timeout=30.0, redistribute_min=0.05,
        sizer=AdaptiveSizer(target_lease_time=0.05, max_size=32),
        watchdog_interval=0.02, grace=4.0, **kw)


async def _run_inprocess() -> float:
    d = _dist()
    d.register_task(TaskDef("sq", _square))
    tids = d.add_work("sq", list(range(N_TICKETS)))
    d.spawn_clients(_profiles())
    t0 = time.perf_counter()
    ok = await d.run_until_done(timeout=120.0)
    elapsed = time.perf_counter() - t0
    assert ok, d.console()
    assert len(d.queue.results_for(tids)) == N_TICKETS
    return elapsed


async def _run_transport() -> tuple[float, dict]:
    d = _dist()
    d.register_task(TaskDef("sq", _square))
    tids = d.add_work("sq", list(range(N_TICKETS)))
    server = TransportServer(d)
    addr = await server.start()
    t0 = time.perf_counter()
    clients, tasks = spawn_remote_clients(addr, _profiles())
    ok = await d.run_until_done(timeout=120.0)
    elapsed = time.perf_counter() - t0
    assert ok, d.console()
    assert len(d.queue.results_for(tids)) == N_TICKETS
    await asyncio.gather(*tasks)
    wire = server.stats()
    await server.stop()
    return elapsed, wire


async def _run_storm() -> dict:
    """The PR 3 re-register storm with every client remote: weights are
    re-published each round; a ticket observing any other round's weights
    is a stale serve.  The bar is zero."""
    d = _dist(keep_alive=True)
    d.add_static("weights", -1)
    d.register_task(TaskDef("rw", _read_weights, static_files=("weights",)))
    server = TransportServer(d)
    addr = await server.start()
    clients, tasks = spawn_remote_clients(addr, _profiles())
    stale = total = 0
    for rnd in range(STORM_ROUNDS):
        d.add_static("weights", rnd)
        tids = d.add_work("rw", list(range(STORM_TICKETS)))
        deadline = time.monotonic() + 60.0
        while True:
            wake = d._wake_event()
            out = d.queue.results_for(tids)
            if out is not None:
                break
            assert time.monotonic() < deadline, d.console()
            await d._wait_on(wake, 0.05)
        for _, w in out:
            total += 1
            stale += (w != rnd)
        d.queue.prune(tids)
    for c in clients:
        await c.stop()
    await asyncio.gather(*tasks, return_exceptions=True)
    await d.shutdown()
    await server.stop()
    return {"rounds": STORM_ROUNDS, "tickets": total, "stale_serves": stale,
            "revalidations": sum(c.revalidations for c in clients),
            "push_invalidations": sum(c.push_invalidations
                                      for c in clients)}


def run_sweep() -> dict:
    """Run all cells; returns the machine-readable results dict
    (``benchmarks/run.py`` writes it as BENCH_transport.json)."""
    inproc = [asyncio.run(_run_inprocess()) for _ in range(REPS)]
    trans = []
    wire = None
    for _ in range(REPS):
        elapsed, wire = asyncio.run(_run_transport())
        trans.append(elapsed)
    t_in = statistics.median(inproc)
    t_tr = statistics.median(trans)
    thr_in = N_TICKETS / t_in
    thr_tr = N_TICKETS / t_tr
    storm = asyncio.run(_run_storm())
    return {
        "workload": {"tickets": N_TICKETS, "clients": N_CLIENTS,
                     "speed": SPEED, "reps": REPS},
        "inprocess": {"makespan_s": round(t_in, 4),
                      "tickets_per_s": round(thr_in, 1)},
        "transport": {"makespan_s": round(t_tr, 4),
                      "tickets_per_s": round(thr_tr, 1),
                      "frames": wire["frames_in"] + wire["frames_out"],
                      "wire_bytes": wire["bytes_in"] + wire["bytes_out"],
                      "bytes_per_ticket": round(
                          (wire["bytes_in"] + wire["bytes_out"])
                          / N_TICKETS, 1)},
        "throughput_ratio": round(thr_tr / thr_in, 3),
        "storm": storm,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write results to this path")
    args = ap.parse_args()
    results = run_sweep()
    print(f"{'cell':<12} {'makespan':>10} {'tickets/s':>10}")
    for cell in ("inprocess", "transport"):
        r = results[cell]
        print(f"{cell:<12} {r['makespan_s']:>9.3f}s "
              f"{r['tickets_per_s']:>10.1f}")
    tr = results["transport"]
    print(f"wire: {tr['frames']} frames, {tr['wire_bytes']} bytes "
          f"({tr['bytes_per_ticket']} bytes/ticket)")
    print(f"throughput ratio (transport/in-process): "
          f"{results['throughput_ratio']}x")
    s = results["storm"]
    print(f"storm over the wire: {s['stale_serves']}/{s['tickets']} stale "
          f"({s['revalidations']} revalidations, "
          f"{s['push_invalidations']} push invalidations)")
    # acceptance bars: coherence survives serialization, and the wire
    # costs at most half the in-process round throughput
    assert s["stale_serves"] == 0, s
    assert results["throughput_ratio"] >= 0.5, results
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
