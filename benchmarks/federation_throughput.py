"""Federation fabric benchmark: 1 vs N distributors over the sharded store.

Discrete-event simulation (virtual clock — runs in milliseconds, fully
deterministic) of browser clients pulling adaptive lease batches through a
**federation of distributors**.  Each distributor member is modelled as a
serialized service station: every lease checkout and every batch submit
occupies its member for ``SERVICE`` virtual seconds — the single-
distributor lock/CPU bottleneck the ROADMAP's federation item targets.
Ticket accounting is the REAL :class:`repro.core.shards.ShardedTicketQueue`
(members lease home shards first and steal across the fabric when dry), so
the benchmark exercises the same peek/checkout min-VCT merge as production.

Scenarios:

  * ``uniform`` / ``bimodal`` client mixes (half the clients 8x faster, the
    paper's desktop-Chrome vs Nexus-7 situation) across 1/2/4 members;
  * ``bimodal+death`` — a 4-member federation whose member 0 dies mid-run,
    taking its clients and their in-flight leases with it; survivors'
    watchdogs release the stranded tickets and steal them.

Each cell reports **makespan** (virtual s until every ticket completes) and
**aggregate throughput** (tickets/s).  The headline assertion mirrors the
acceptance bar: a 4-member federation sustains >= 1.5x the single
distributor's throughput on the bimodal mix, and the death run completes
every ticket.

Usage:
  PYTHONPATH=src python benchmarks/federation_throughput.py [--json out.json]
                                                            [--smoke]
"""
from __future__ import annotations

import argparse
import heapq
import itertools
import json
import sys

sys.path.insert(0, "src")

from repro.core.distributor import AdaptiveSizer
from repro.core.federation import grant_has_foreign_tickets
from repro.core.shards import ShardedTicketQueue

RTT = 0.05          # client <-> distributor round-trip latency (s)
SERVICE = 0.02      # distributor service time per lease/submit request (s)
N_TICKETS = 600
N_CLIENTS = 16
N_TASKS = 8         # distinct task names -> tickets spread across shards
BASE_RATE = 10.0    # work units / s for a "slow" client
GRACE = 3.0


class SimClock:
    """Injectable virtual clock (docs/ARCHITECTURE.md §Injectable clock)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def client_mix(kind: str):
    """[(name, work-units/s)] for the requested mix."""
    if kind == "uniform":
        return [(f"c{i}", BASE_RATE) for i in range(N_CLIENTS)]
    if kind == "bimodal":
        return [(f"fast{i}", 8 * BASE_RATE) for i in range(N_CLIENTS // 2)] \
            + [(f"slow{i}", BASE_RATE) for i in range(N_CLIENTS // 2)]
    raise KeyError(kind)


def simulate(mix: str, n_members: int, *, n_tickets: int = N_TICKETS,
             death_at: float | None = None,
             redistribute_min: float = 0.5, timeout: float = 300.0) -> dict:
    """One benchmark cell.  The event heap holds (time, seq, kind, client,
    payload); lease/submit requests pass through their member's serialized
    service station (``busy[m]``) before touching the shared queue."""
    clock = SimClock()
    n_shards = max(2 * n_members, 2)
    q = ShardedTicketQueue(n_shards, timeout=timeout,
                           redistribute_min=redistribute_min, clock=clock)
    for task in range(N_TASKS):
        q.add_many(f"task{task}", list(range(n_tickets // N_TASKS)),
                   work=1.0)
    total = (n_tickets // N_TASKS) * N_TASKS

    sizer = AdaptiveSizer(target_lease_time=0.5, max_size=8)
    home = {m: [q.shards[j] for j in range(n_shards) if j % n_members == m]
            for m in range(n_members)}

    clients = client_mix(mix)
    member_of = {name: i % n_members for i, (name, _) in enumerate(clients)}
    speed = dict(clients)
    member_alive = [True] * n_members
    client_alive = {name: True for name, _ in clients}
    busy = [0.0] * n_members
    steals = 0
    stranded_at_death = 0
    completed_at_death = None

    seq = itertools.count()
    events: list = []
    for name, _ in clients:
        heapq.heappush(events, (0.0, next(seq), "wake", name, None))
    if death_at is not None:
        heapq.heappush(events, (death_at, next(seq), "death", "", None))

    makespan = None

    def service(member: int, t: float) -> float:
        """FIFO station: request arriving at ``t`` completes at
        max(t, busy) + SERVICE."""
        start = max(t, busy[member])
        busy[member] = start + SERVICE
        return busy[member]

    while events:
        t, _, kind, name, payload = heapq.heappop(events)
        clock.t = t
        if q.all_done():
            makespan = makespan if makespan is not None else t
            break

        if kind == "death":
            # member 0 dies: clients gone, in-flight leases stranded until
            # a survivor's watchdog (the scheduled "watchdog" events,
            # member-agnostic: any member's watchdog patrols the shared
            # store) releases them for stealing
            member_alive[0] = False
            for cname, m in member_of.items():
                if m == 0:
                    client_alive[cname] = False
            stranded_at_death = len(q.outstanding_leases())
            completed_at_death = q.snapshot()["executed"]
            continue

        if name and not client_alive.get(name, False):
            continue

        if kind == "wake":
            m = member_of[name]
            heapq.heappush(events, (service(m, t), next(seq), "leased",
                                    name, None))
        elif kind == "leased":
            m = member_of[name]
            stats = q.stats.get(name)
            n = sizer.lease_size(stats)
            batch = q.lease(name, n, shards=home[m])
            if batch is None and len(home[m]) < n_shards:
                batch = q.lease(name, n)          # steal across the fabric
                if batch is not None and grant_has_foreign_tickets(
                        batch, home[m]):
                    steals += 1
            if batch is None:
                heapq.heappush(events, (t + redistribute_min / 4, next(seq),
                                        "wake", name, None))
                continue
            eta = sizer.expected_duration(stats, len(batch.ticket_ids))
            batch.expected_duration = eta
            if eta is not None:
                heapq.heappush(events,
                               (batch.issued_at + GRACE * max(eta, 1e-3),
                                next(seq), "watchdog", "", batch.lease_id))
            finish = t + RTT + batch.work / speed[name]
            heapq.heappush(events, (finish, next(seq), "finish", name,
                                    batch))
        elif kind == "finish":
            m = member_of[name]
            heapq.heappush(events, (service(m, t), next(seq), "submitted",
                                    name, payload))
        elif kind == "submitted":
            batch = payload
            q.submit_batch(batch.lease_id,
                           {tid: tid for tid in batch.ticket_ids}, name)
            if q.all_done():
                makespan = t
                break
            heapq.heappush(events, (t, next(seq), "wake", name, None))
        elif kind == "watchdog":
            q.release(payload, client_failed=True)

    if makespan is None:
        makespan = clock.t
    snap = q.snapshot()
    out = {
        "members": n_members,
        "makespan_s": round(makespan, 3),
        "throughput_tps": round(snap["executed"] / max(makespan, 1e-9), 2),
        "completed": snap["executed"],
        "total": total,
        "steals": steals,
        "lease_releases": snap["lease_releases"],
        "redistributions": snap["redistributions"],
    }
    if death_at is not None:
        out["completed_at_death"] = completed_at_death
        out["stranded_at_death"] = stranded_at_death
    return out


def run_sweep(*, n_tickets: int = N_TICKETS) -> dict:
    """All cells: {mix: {config: metrics}} plus the headline speedups."""
    out: dict = {}
    for mix in ("uniform", "bimodal"):
        out[mix] = {f"fed-{n}": simulate(mix, n, n_tickets=n_tickets)
                    for n in (1, 2, 4)}
    # member-death scenario: kill member 0 roughly mid-run
    death_at = 0.5 * out["bimodal"]["fed-4"]["makespan_s"]
    out["bimodal+death"] = {
        "fed-4-kill-m0": simulate("bimodal", 4, n_tickets=n_tickets,
                                  death_at=death_at)}
    bi = out["bimodal"]
    out["speedup_4v1_bimodal"] = round(
        bi["fed-4"]["throughput_tps"] / bi["fed-1"]["throughput_tps"], 2)
    out["client_mix"] = {"clients": N_CLIENTS,
                         "fast_rate": 8 * BASE_RATE, "slow_rate": BASE_RATE,
                         "service_s": SERVICE, "rtt_s": RTT}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced size (CI smoke)")
    args = ap.parse_args()
    results = run_sweep(n_tickets=200 if args.smoke else N_TICKETS)

    hdr = f"{'mix':<16}{'config':<16}{'makespan(s)':>12}{'tickets/s':>11}" \
          f"{'steals':>8}{'released':>10}{'done':>7}"
    print(hdr)
    print("-" * len(hdr))
    for mix in ("uniform", "bimodal", "bimodal+death"):
        for config, m in results[mix].items():
            print(f"{mix:<16}{config:<16}{m['makespan_s']:>12.2f}"
                  f"{m['throughput_tps']:>11.1f}{m['steals']:>8}"
                  f"{m['lease_releases']:>10}{m['completed']:>7}")

    speedup = results["speedup_4v1_bimodal"]
    print(f"\nbimodal: 4-member federation sustains {speedup:.2f}x the "
          f"single distributor's aggregate ticket throughput")
    assert speedup >= 1.5, \
        f"4-member federation must reach >= 1.5x single-distributor " \
        f"throughput on the bimodal mix (got {speedup:.2f}x)"
    death = results["bimodal+death"]["fed-4-kill-m0"]
    assert death["completed"] == death["total"], \
        f"member death must not lose tickets: {death}"
    assert death["completed_at_death"] < death["total"], \
        "death must land mid-run to prove recovery"
    print(f"member-death run: all {death['completed']} tickets completed "
          f"({death['completed_at_death']} done at kill time, "
          f"{death['stranded_at_death']} leases stranded, "
          f"{death['steals']} steals)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
