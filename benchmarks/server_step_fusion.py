"""Server-step fusion benchmark: fused kernel vs tree_map reference.

Times ONE federated server step — per-member clip, work-weighted mean,
modified-AdaGrad update — over the Figure-4 CNN's parameter tree with
M=8 arrived members, comparing

  * ``baseline`` — the seed's unfused tree_map pipeline, exactly what
    ``FederatedTrainingLoop`` ran before the ServerStep refactor: eager
    ``weighted_grad_mean`` followed by eager ``opt.update`` (separate
    passes, materialized intermediate trees);
  * ``tree``  — :class:`TreeServerStep`: the same pipeline under one
    end-to-end ``jax.jit`` (the loop's new default reference);
  * ``fused`` — :class:`FusedServerStep`: clip + mean + update as ONE
    fused pass (the Pallas flat-buffer kernel on TPU; off-TPU the
    identical math leafwise in one XLA program — zero extra copies).

The gate is the **ratio** of interleaved best-of-trials times (fused /
unfused tree_map baseline), compared against the checked-in
``benchmarks/baselines/server_step_baseline.json`` with ×1.2 headroom —
ratios travel across machines far better than absolute microseconds.
A bit-equivalence bar (interpret-mode flat kernel vs the reference,
FABRIC_CNN-sized) runs first: a fast-but-wrong fused step must fail
before any timing is reported.

Usage:
  PYTHONPATH=src python benchmarks/server_step_fusion.py [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.paper_cnn import FABRIC_CNN, FIG4_CNN
from repro.core.split_parallel import weighted_grad_mean
from repro.models.cnn import init_cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree
from repro.train_fabric import (FusedServerStep, ServerStep,
                                TreeServerStep, param_count)

MEMBERS = 8
LR = 0.01
CLIP = 1.0
BASELINE_PATH = "benchmarks/baselines/server_step_baseline.json"
HEADROOM = 1.2


def make_round(ccfg, *, members: int = MEMBERS, seed: int = 0):
    """One round's server-side inputs: params + opt state + M member
    gradient trees (deterministic), work weights."""
    params = jax.device_get(
        values_tree(init_cnn(jax.random.PRNGKey(seed), ccfg)))
    opt = adagrad(LR)
    state = opt.init(params)
    rng = np.random.default_rng(seed)
    grads = [jax.tree_util.tree_map(
        lambda p: rng.normal(size=p.shape).astype(np.float32), params)
        for _ in range(members)]
    works = [float(w) for w in rng.uniform(0.5, 2.0, size=members)]
    return opt, params, state, grads, works


class UnfusedBaselineStep(ServerStep):
    """The seed's server path, verbatim: eager ``weighted_grad_mean``
    then eager ``opt.update`` — the pre-refactor tree_map pipeline the
    fused step is gated against."""

    name = "unfused_baseline"

    def __init__(self, opt):
        self.opt = opt

    def step(self, grads, works, params, opt_state):
        g = weighted_grad_mean(grads, works)
        return self.opt.update(g, opt_state, params)


def time_steps(steps, grads, works, params, opt_state,
               trials: int) -> list[float]:
    """Best (minimum) seconds per server step for each competitor,
    measured INTERLEAVED (one timing of each per trial round).
    Interleaving lands machine-load drift on all competitors equally,
    and the minimum estimates the interference-free cost — together
    they keep the ratio gate stable where back-to-back medians flap on
    a shared box."""
    for step in steps:                      # compile warmup
        jax.block_until_ready(step.step(grads, works, params, opt_state))
    ts: list[list[float]] = [[] for _ in steps]
    for _ in range(trials):
        for i, step in enumerate(steps):
            t0 = time.perf_counter()
            jax.block_until_ready(
                step.step(grads, works, params, opt_state))
            ts[i].append(time.perf_counter() - t0)
    return [min(t) for t in ts]


def bit_equivalence_bar() -> None:
    """Interpret-mode fused step must be bitwise equal to the jitted
    tree_map reference (FABRIC_CNN-sized so the interpreter stays fast)."""
    opt, params, state, grads, works = make_round(FABRIC_CNN, seed=3)
    p1, s1 = TreeServerStep(opt, clip_norm=CLIP).step(
        grads, works, params, state)
    p2, s2 = FusedServerStep(opt, lr=LR, clip_norm=CLIP,
                             mode="interpret").step(
        grads, works, params, state)
    for a, b in zip(jax.tree_util.tree_leaves((p1, s1["acc"])),
                    jax.tree_util.tree_leaves((p2, s2["acc"]))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "interpret-mode fused step diverged from the reference"


def load_baseline() -> dict:
    with open(BASELINE_PATH) as f:
        return json.load(f)


def run(*, trials: int = 30) -> dict:
    bit_equivalence_bar()
    opt, params, state, grads, works = make_round(FIG4_CNN)
    # headline timing is clip-free: the seed pipeline being gated against
    # had no clipping, so the comparison is pass-for-pass (clip-enabled
    # correctness is the bit-equivalence bar's job)
    baseline = UnfusedBaselineStep(opt)
    tree = TreeServerStep(opt)
    fused = FusedServerStep(opt, lr=LR)
    t_base, t_tree, t_fused = time_steps(
        (baseline, tree, fused), grads, works, params, state, trials)
    return {
        "model": FIG4_CNN.name,
        "model_params": param_count(params),
        "members": MEMBERS,
        "trials": trials,
        "fused_mode": fused.mode,
        "baseline_best_us": round(t_base * 1e6, 1),
        "tree_jit_best_us": round(t_tree * 1e6, 1),
        "fused_best_us": round(t_fused * 1e6, 1),
        "fused_over_tree_ratio": round(t_fused / t_base, 4),
        "bit_equivalence": "passed",
    }


def check(results: dict) -> None:
    """Acceptance bars (shared with benchmarks/run.py): the fused step
    must beat the unfused tree_map baseline, and must not regress past
    the checked-in baseline ratio with ×1.2 headroom."""
    ratio = results["fused_over_tree_ratio"]
    assert ratio < 1.0, \
        f"fused server step must beat the tree_map baseline " \
        f"(ratio {ratio})"
    base = load_baseline()["fused_over_tree_ratio"]
    assert ratio <= base * HEADROOM, \
        f"fused/tree ratio {ratio} regressed past baseline " \
        f"{base} x{HEADROOM}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    ap.add_argument("--trials", type=int, default=30)
    args = ap.parse_args()
    results = run(trials=args.trials)
    print(f"{results['model']} ({results['model_params']} params), "
          f"M={results['members']} members, mode={results['fused_mode']}")
    print(f"tree_map baseline : {results['baseline_best_us']:>10.1f} us")
    print(f"tree_map jitted   : {results['tree_jit_best_us']:>10.1f} us")
    print(f"fused             : {results['fused_best_us']:>10.1f} us")
    print(f"ratio fused/baseline: {results['fused_over_tree_ratio']:.3f} "
          f"(checked-in {load_baseline()['fused_over_tree_ratio']}, "
          f"headroom x{HEADROOM})")
    check(results)
    print("all server-step bars passed")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
