"""Cache-coherence benchmark: versioned invalidation vs clear()-everything.

Virtual-clock simulation of a **re-register storm**: a §4.1-style training
loop re-publishes its weight static every round (and the task code every
few rounds) while browsers keep pulling version-pinned tickets through
per-member edge caches.  All the moving parts are the real production
objects — :class:`~repro.core.distributor.HttpServerBase` (versioned
registry), :class:`~repro.core.federation.EdgeCache` (coherent edges),
:class:`~repro.core.distributor.BrowserNodeBase` (pin-aware browser
caches) and :class:`~repro.core.shards.ShardedTicketQueue` (version-
stamped tickets through the lease/merge path).

Three strategies over the identical workload:

  * ``versioned``       — this PR: tickets pin the registry coherence
                          version, edges take push invalidations,
                          browsers revalidate conditionally.
  * ``clear-all``       — the only pre-PR remedy: no versioning; every
                          re-register nukes every edge and browser cache,
                          so nothing is ever stale but everything
                          (including the immutable dataset) re-downloads.
  * ``no-invalidation`` — the pre-PR bug left alone: no versioning, no
                          clears; caches serve re-registered keys stale
                          forever.

Metrics per cell: **stale_serves** (tickets executed against older code
or weights than their creation-time snapshot) and **origin egress**
(payload units out of the origin; a conditional not-modified reply costs
``HEADER_COST``).  The headline assertions mirror the acceptance bar:
``versioned`` has ZERO stale serves (``no-invalidation`` has many) and
saves a large fraction of ``clear-all``'s egress.

Usage:
  PYTHONPATH=src python benchmarks/cache_coherence.py [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.core.distributor import (BrowserNodeBase, ClientProfile,
                                    HttpServerBase, TaskDef)
from repro.core.federation import EdgeCache
from repro.core.shards import ShardedTicketQueue

ROUNDS = 30            # training rounds (one weight re-register each)
CODE_EVERY = 5         # task code re-registered every N rounds
TICKETS_PER_ROUND = 16
N_EDGES = 2
N_BROWSERS = 8         # split evenly across edges
LEASE_SIZE = 4
EXEC_TIME = 0.01       # virtual s per executed ticket

# payload sizes in abstract units (origin egress = downloads x size)
SIZES = {"task:work": 5.0, "weights": 40.0, "dataset": 200.0}
HEADER_COST = 0.05     # a not-modified reply is a counter bump, not a copy


class SimClock:
    """Injectable virtual clock (docs/ARCHITECTURE.md §Injectable clock)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class SimBrowser(BrowserNodeBase):
    """A bare browser node (real cache logic, no thread/event loop)."""

    def __init__(self, dist, name: str, capacity: int = 16):
        self._init_browser(dist, ClientProfile(name=name,
                                               cache_capacity=capacity))


def make_task(code_gen: int) -> TaskDef:
    """Task code generation ``code_gen``: running it reveals exactly which
    code and which weights the client actually used."""
    def run(args, static):
        return {"code": code_gen, "weights": static["weights"]}
    return TaskDef("work", run, static_files=("weights", "dataset"))


def simulate(strategy: str) -> dict:
    """One cell: the re-register storm under ``strategy``."""
    assert strategy in ("versioned", "clear-all", "no-invalidation")
    versioned = strategy == "versioned"
    clock = SimClock()
    origin = HttpServerBase()
    edges = [EdgeCache(origin, name=f"edge{i}", capacity=64,
                       subscribe=versioned)
             for i in range(N_EDGES)]
    browsers = [SimBrowser(edges[i % N_EDGES], f"b{i}")
                for i in range(N_BROWSERS)]
    q = ShardedTicketQueue(4, clock=clock)

    origin.add_static("dataset", "immutable-training-data")  # never changes
    origin.add_static("weights", {"gen": 0})
    origin.register_task(make_task(0))

    def clear_everything():
        for e in edges:
            e.clear()
        for b in browsers:
            b.cache.clear()

    stale_serves = 0
    executed = 0
    # creation-time snapshot each ticket must not run BEHIND
    expected: dict[int, tuple[int, int]] = {}   # tid -> (code_gen, w_gen)

    code_gen = 0
    for rnd in range(ROUNDS):
        # --- the storm: weights every round, code every CODE_EVERY ------
        if rnd > 0:
            origin.add_static("weights", {"gen": rnd})
            if rnd % CODE_EVERY == 0:
                code_gen = rnd
                origin.register_task(make_task(code_gen))
            if strategy == "clear-all":
                clear_everything()

        pin = origin.task_version("work") if versioned else 0
        tids = q.add_many("work", list(range(TICKETS_PER_ROUND)),
                          task_version=pin)
        for tid in tids:
            expected[tid] = (code_gen, rnd)

        # --- browsers drain the round through their edges ----------------
        while q.results_for(tids) is None:
            progress = False
            for b in browsers:
                batch = q.lease(b.profile.name, LEASE_SIZE)
                if batch is None:
                    continue
                progress = True
                results = {}
                for t in batch.tickets:
                    task = b._get_task(t.task_name, t.task_version)
                    static = b._get_static(task, t.task_version)
                    out = task.run(t.args, static)
                    clock.t += EXEC_TIME
                    executed += 1
                    want_code, want_w = expected[t.ticket_id]
                    if (out["code"] < want_code
                            or out["weights"]["gen"] < want_w):
                        stale_serves += 1
                    results[t.ticket_id] = out
                q.submit_batch(batch.lease_id, results, b.profile.name)
            assert progress, "simulation wedged"
        q.prune(tids)

    egress = sum(origin.download_count[k] * SIZES[k]
                 for k in origin.download_count)
    egress += sum(origin.revalidation_count.values()) * HEADER_COST
    return {
        "strategy": strategy,
        "stale_serves": stale_serves,
        "executed": executed,
        "origin_egress_units": round(egress, 2),
        "origin_downloads": dict(origin.download_count),
        "origin_revalidations": dict(origin.revalidation_count),
        "edge_invalidations": sum(e.invalidations for e in edges),
        "edge_revalidations": sum(sum(e.revalidation_count.values())
                                  for e in edges),
        "browser_revalidations": sum(b.revalidations for b in browsers),
        "edge_hit_rate": round(
            sum(e.cache.hits for e in edges)
            / max(sum(sum(e.download_count.values()) for e in edges), 1),
            3),
        "virtual_makespan_s": round(clock.t, 3),
    }


def run_sweep() -> dict:
    out = {s: simulate(s)
           for s in ("versioned", "clear-all", "no-invalidation")}
    v, c = out["versioned"], out["clear-all"]
    out["egress_saved_vs_clear_pct"] = round(
        100.0 * (1 - v["origin_egress_units"] / c["origin_egress_units"]), 1)
    out["config"] = {"rounds": ROUNDS, "code_every": CODE_EVERY,
                     "tickets_per_round": TICKETS_PER_ROUND,
                     "edges": N_EDGES, "browsers": N_BROWSERS,
                     "sizes": SIZES, "header_cost": HEADER_COST}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    args = ap.parse_args()
    results = run_sweep()

    hdr = f"{'strategy':<18}{'stale':>7}{'egress(u)':>12}{'reval':>7}" \
          f"{'edge-hit':>10}"
    print(hdr)
    print("-" * len(hdr))
    for s in ("versioned", "clear-all", "no-invalidation"):
        m = results[s]
        reval = (sum(m["origin_revalidations"].values())
                 + m["edge_revalidations"])
        print(f"{s:<18}{m['stale_serves']:>7}"
              f"{m['origin_egress_units']:>12.1f}"
              f"{reval:>7}"
              f"{m['edge_hit_rate']:>10.3f}")

    v = results["versioned"]
    n = results["no-invalidation"]
    saved = results["egress_saved_vs_clear_pct"]
    print(f"\nversioned invalidation: {v['stale_serves']} stale serves "
          f"across {v['executed']} executions ({n['stale_serves']} without "
          f"invalidation), {saved:.1f}% origin egress saved vs "
          f"clear()-everything")
    assert v["stale_serves"] == 0, \
        f"versioned strategy must never serve stale: {v}"
    assert n["stale_serves"] > 0, \
        "the no-invalidation baseline must exhibit the staleness bug " \
        f"(else the benchmark proves nothing): {n}"
    assert results["clear-all"]["stale_serves"] == 0   # the old remedy works
    assert saved > 30.0, \
        f"versioned must save substantial egress vs clear() (got {saved}%)"

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
