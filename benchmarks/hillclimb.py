"""§Perf hillclimb driver: applies named optimization variants to one
(arch × shape) pair and reports the roofline-term deltas vs baseline.

Each variant is a context-managed patch (sharding rule change, config
change, remat policy, ...) so the hypothesis → change → measure → validate
loop in EXPERIMENTS.md §Perf is a single command per iteration:

  PYTHONPATH=src python -m benchmarks.hillclimb \
      --arch dbrx-132b --shape train_4k --variants baseline,cap_1.0
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import contextlib
import dataclasses
import json

import repro.sharding.rules as R
from repro.configs import base as config_base
from repro.configs.base import MoEConfig


# ---------------------------------------------------------------------------
# Variants
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def v_baseline(arch, shape):
    yield {}


@contextlib.contextmanager
def v_cap_1_0(arch, shape):
    """MoE capacity factor 1.25 -> 1.0 (−20% expert FLOPs/bytes, more
    drops)."""
    mod = config_base._MODULE_FOR_ARCH[arch]
    import importlib
    m = importlib.import_module(f"repro.configs.{mod}")
    orig = m.CONFIG
    if orig.is_moe:
        m.CONFIG = dataclasses.replace(
            orig, moe=dataclasses.replace(orig.moe, capacity_factor=1.0))
    try:
        yield {}
    finally:
        m.CONFIG = orig


@contextlib.contextmanager
def v_no_remat(arch, shape):
    """Disable activation rematerialisation (memory up, recompute FLOPs
    down)."""
    yield {"run_overrides": {"remat": False}}


@contextlib.contextmanager
def v_tp_decode(arch, shape):
    """Decode with weights resident in pure TP (no FSDP all-gather per
    step): embed/head_embed rules -> None.  Only valid when params_bf16/16
    shards fit HBM."""
    orig = {k: dict(v) for k, v in R.AXIS_RULES.items()}
    for strat in R.AXIS_RULES:
        R.AXIS_RULES[strat] = dict(R.AXIS_RULES[strat]) | {
            "embed": None, "head_embed": None}
    try:
        yield {}
    finally:
        R.AXIS_RULES.update(orig)


@contextlib.contextmanager
def v_seq_shard_train(arch, shape):
    """Shard the sequence dim of train/prefill activations over 'model'
    instead of sharding attention heads (context-parallel style)."""
    orig = {k: dict(v) for k, v in R.AXIS_RULES.items()}
    for strat in R.AXIS_RULES:
        R.AXIS_RULES[strat] = dict(R.AXIS_RULES[strat]) | {
            "seq": "model", "heads": None, "mlp": None}
    try:
        yield {}
    finally:
        R.AXIS_RULES.update(orig)


@contextlib.contextmanager
def v_expert_2d(arch, shape):
    """Shard experts over BOTH mesh axes (128 experts -> 256 shards needs
    (data,model)); halves per-shard expert weights for many-expert MoE."""
    orig = {k: dict(v) for k, v in R.AXIS_RULES.items()}
    for strat in R.AXIS_RULES:
        R.AXIS_RULES[strat] = dict(R.AXIS_RULES[strat]) | {
            "expert": ("data", "model"), "embed": None}
    try:
        yield {}
    finally:
        R.AXIS_RULES.update(orig)


@contextlib.contextmanager
def v_dp_full(arch, shape):
    yield {"strategy": "dp_full"}


@contextlib.contextmanager
def v_fsdp_tp(arch, shape):
    yield {"strategy": "fsdp_tp"}


@contextlib.contextmanager
def v_split_sequential(arch, shape):
    yield {"strategy": "split_sequential"}


@contextlib.contextmanager
def v_split_server_sharded(arch, shape):
    yield {"strategy": "split_server_sharded"}


@contextlib.contextmanager
def v_head_sync_1(arch, shape):
    yield {"run_overrides": {"head_sync_period": 1}}


@contextlib.contextmanager
def v_grad_accum_4(arch, shape):
    """Split the global batch into 4 microbatches (gradient accumulation):
    ~4x lower peak activation memory, identical math."""
    yield {"run_overrides": {"grad_accum": 4}}


@contextlib.contextmanager
def v_grad_accum_8(arch, shape):
    yield {"run_overrides": {"grad_accum": 8}}


@contextlib.contextmanager
def v_loss_chunks_8(arch, shape):
    """Fused vocab-chunked head+loss: the (B,S,V) logits tensor never
    materialises (online logsumexp over 8 vocab chunks, remat'd)."""
    yield {"run_overrides": {"loss_chunks": 8, "strategy": "fsdp_tp"},
           "strategy": "fsdp_tp"}


@contextlib.contextmanager
def v_ga8_bf16(arch, shape):
    """grad_accum=8 + bf16 params (f32 adagrad accumulator kept): halves
    the parameter/gradient bytes on top of the activation win."""
    yield {"run_overrides": {"grad_accum": 8, "param_dtype": "bfloat16"}}


@contextlib.contextmanager
def v_window_4k(arch, shape):
    """Sliding-window attention (4096) — the flash/block-sparse analogue
    for archs whose native context is 4k anyway (e.g. minitron)."""
    mod = config_base._MODULE_FOR_ARCH[arch]
    import importlib
    m = importlib.import_module(f"repro.configs.{mod}")
    orig = m.CONFIG
    m.CONFIG = dataclasses.replace(orig, sliding_window=4096)
    try:
        yield {}
    finally:
        m.CONFIG = orig


@contextlib.contextmanager
def v_repl_batch_decode(arch, shape):
    """Replicated-batch decode layout: batch -> None so contraction-dim-
    sharded (FSDP) weights stay RESIDENT — GSPMD partial-sums the (tiny)
    activations instead of all-gathering the (huge) weights each step, and
    the shard_map MoE takes its partial-sum schedule.  Trade: the KV cache
    loses its batch sharding (stays kv_seq-sharded over 'model')."""
    orig = {k: dict(v) for k, v in R.AXIS_RULES.items()}
    for strat in R.AXIS_RULES:
        R.AXIS_RULES[strat] = dict(R.AXIS_RULES[strat]) | {"batch": None}
    try:
        yield {}
    finally:
        R.AXIS_RULES.update(orig)


@contextlib.contextmanager
def v_repl_batch_kv2d(arch, shape):
    """repl_batch_decode + KV cache sharded over BOTH axes (kv_seq ->
    (data, model)): keeps the resident-weight collective win and removes
    the cache replication across 'data'."""
    orig = {k: dict(v) for k, v in R.AXIS_RULES.items()}
    for strat in R.AXIS_RULES:
        R.AXIS_RULES[strat] = dict(R.AXIS_RULES[strat]) | {
            "batch": None, "kv_seq": ("data", "model")}
    import repro.launch.steps as S
    orig_make = S.make_rules

    def patched(strategy, mesh, shape_, global_batch=None, **kw):
        rules = orig_make(strategy, mesh, shape_, global_batch, **kw)
        rules["batch"] = None
        rules["kv_seq"] = tuple(a for a in ("data", "model")
                                if a in mesh.axis_names)
        return rules

    S.make_rules = patched
    try:
        yield {}
    finally:
        R.AXIS_RULES.update(orig)
        S.make_rules = orig_make


VARIANTS = {
    "baseline": v_baseline,
    "repl_batch_decode": v_repl_batch_decode,
    "repl_batch_kv2d": v_repl_batch_kv2d,
    "cap_1.0": v_cap_1_0,
    "no_remat": v_no_remat,
    "tp_decode": v_tp_decode,
    "seq_shard_train": v_seq_shard_train,
    "expert_2d": v_expert_2d,
    "dp_full": v_dp_full,
    "fsdp_tp": v_fsdp_tp,
    "split_sequential": v_split_sequential,
    "split_server_sharded": v_split_server_sharded,
    "head_sync_1": v_head_sync_1,
    "grad_accum_4": v_grad_accum_4,
    "grad_accum_8": v_grad_accum_8,
    "ga8_bf16": v_ga8_bf16,
    "loss_chunks_8": v_loss_chunks_8,
    "window_4k": v_window_4k,
}


def measure(arch: str, shape: str, variant: str, *, multi_pod=False) -> dict:
    from repro.launch import dryrun

    with VARIANTS[variant](arch, shape) as opts:
        strategy = opts.get("strategy")
        overrides = opts.get("run_overrides", {})
        if overrides:
            orig_run_cls = dryrun.RunConfig
            def patched(*a, **kw):
                kw.update(overrides)
                return orig_run_cls(*a, **kw)
            dryrun.RunConfig = patched
        try:
            rec = dryrun.run_one(arch, shape, strategy=strategy,
                                 multi_pod=multi_pod, verbose=False)
        finally:
            if overrides:
                dryrun.RunConfig = orig_run_cls
    rec["variant"] = variant
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    rows = []
    base = None
    for v in args.variants.split(","):
        r = measure(args.arch, args.shape, v)
        rows.append(r)
        if v == "baseline" or base is None:
            base = r
        print(f"{args.arch} x {args.shape} [{v:>20s}] "
              f"t=({r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
              f"{r['t_collective_s']:.4f})s "
              f"dom={r['dominant']} "
              f"peak={r['peak_bytes_per_device']/2**30:.1f}GiB "
              f"Δdom={_delta(base, r):+.1%}", flush=True)
    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")


def _delta(base, r):
    key = {"compute": "t_compute_s", "memory": "t_memory_s",
           "collective": "t_collective_s"}[base["dominant"]]
    if base[key] == 0:
        return 0.0
    return (r[key] - base[key]) / base[key]


if __name__ == "__main__":
    main()
