"""Table 4 reproduction: batches learned per minute on the Figure-2 deep
CNN (conv16/conv20/conv20 + FC, CIFAR-like 32x32x3 inputs, mini-batch 50).

Paper comparison: Sukiyaki (GPGPU via WebCL) vs ConvNetJS (single-threaded
JS) — 545.4 vs 17.6 batches/min on Node.js (~30x).  TPU-framework analogue:
the jit-compiled training step (Sukiyaki role: compiled, accelerator-
oriented) vs the same math dispatched op-by-op without compilation
(ConvNetJS role: interpreter-bound).  Both run the identical modified-
AdaGrad update.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import FIG2_CNN
from repro.data import clustered_images
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def _make_step(ccfg, opt):
    def step(params, opt_state, x, y):
        def loss_fn(p):
            return cnn.nll_loss(cnn.forward(p, ccfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss
    return step


def batches_per_min(jit: bool, *, seconds: float = 10.0, batch: int = 50):
    ccfg = FIG2_CNN
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    opt = adagrad(0.01, beta=1.0)
    opt_state = opt.init(params)
    images, labels = clustered_images(512, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=0)
    step = _make_step(ccfg, opt)
    if jit:
        step = jax.jit(step)
    x = jnp.asarray(images[:batch])
    y = jnp.asarray(labels[:batch])
    # warmup (compile)
    params, opt_state, loss = step(params, opt_state, x, y)
    jax.block_until_ready(loss)
    n = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        j = (n * batch) % (len(images) - batch)
        x = jnp.asarray(images[j:j + batch])
        y = jnp.asarray(labels[j:j + batch])
        params, opt_state, loss = step(params, opt_state, x, y)
        jax.block_until_ready(loss)
        n += 1
    dt = time.perf_counter() - t0
    return n / dt * 60.0


def run(*, seconds: float = 8.0):
    with jax.disable_jit():
        eager = batches_per_min(False, seconds=seconds)
    jitted = batches_per_min(True, seconds=seconds)
    return [{"impl": "sukiyaki-analog (jit)", "batches_per_min":
             round(jitted, 2)},
            {"impl": "convnetjs-analog (op-by-op)", "batches_per_min":
             round(eager, 2)},
            {"impl": "speedup", "batches_per_min":
             round(jitted / max(eager, 1e-9), 1)}]


if __name__ == "__main__":
    for r in run():
        print(r)
