"""Figure 5 reproduction: the paper's distributed deep-learning algorithm —
conv layers trained data-parallel on (simulated browser) clients via
Sashimi, the fully-connected layer trained on the server CONCURRENTLY from
the feature activations the clients return.

Reported exactly like the paper: conv-layer training speed (batches/min)
and FC-layer training speed, varying clients 1..4, plus the stand-alone
(sequential single-machine) baseline.  Expected qualitative result: conv
speed scales with clients; FC speed exceeds stand-alone independent of the
client count (the server trains FC while awaiting conv work).

HOST NOTE: one cpu core — client conv work uses measured-cost timed work
units (see table2_knn.py); the gradient/feature math itself is validated
for real in tests/ and examples/.  The server FC updates and the whole
Sashimi protocol run for real.
"""
from __future__ import annotations

import queue as queue_mod
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import FIG4_CNN
from repro.core.distributor import ClientProfile, Distributor, TaskDef
from repro.data import clustered_images
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def _setup():
    ccfg = FIG4_CNN
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    images, labels = clustered_images(512, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=0)
    return ccfg, params, images, labels


def _conv_fn(ccfg, opt_fc):
    @jax.jit
    def conv_grads_task(conv_p, fc_p, x, y):
        def loss_fn(cp):
            feats = cnn.conv_features({**cp, **fc_p}, ccfg, x)
            logits = cnn.fc_logits({**cp, **fc_p}, ccfg, feats)
            return cnn.nll_loss(logits, y), feats
        (loss, feats), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(conv_p)
        return grads, feats, loss

    @jax.jit
    def fc_step(fc_p, fc_opt, feats, y):
        def loss_fn(fp):
            return cnn.nll_loss(cnn.fc_logits(fp, ccfg, feats), y)
        loss, grads = jax.value_and_grad(loss_fn)(fc_p)
        fc_p, fc_opt = opt_fc.update(grads, fc_opt, fc_p)
        return fc_p, fc_opt, loss

    return conv_grads_task, fc_step


def _measure_unit_costs():
    """Real per-batch costs for the conv (client) and fc (server) halves."""
    ccfg, params, images, labels = _setup()
    opt_fc = adagrad(0.01)
    conv_grads_task, fc_step = _conv_fn(ccfg, opt_fc)
    conv_p = {"convs": params["convs"]}
    fc_p = {"fc": params["fc"]}
    fc_opt = opt_fc.init(fc_p)
    bs = ccfg.batch_size
    x, y = jnp.asarray(images[:bs]), jnp.asarray(labels[:bs])
    g, feats, _ = conv_grads_task(conv_p, fc_p, x, y)   # compile
    jax.block_until_ready(feats)
    t0 = time.perf_counter()
    for _ in range(3):
        g, feats, loss = conv_grads_task(conv_p, fc_p, x, y)
        jax.block_until_ready(loss)
    w_conv = (time.perf_counter() - t0) / 3
    fc_p2, fc_opt2, loss = fc_step(fc_p, fc_opt, feats, y)  # compile
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(3):
        _, _, loss = fc_step(fc_p, fc_opt, feats, y)
        jax.block_until_ready(loss)
    w_fc = (time.perf_counter() - t0) / 3
    return w_conv, w_fc


def standalone_speed(w_conv: float, w_fc: float):
    """Sequential baseline: each batch pays conv + fc serially."""
    per_batch = w_conv + w_fc
    bpm = 60.0 / per_batch
    return bpm, bpm


def split_speed(n_clients: int, w_conv: float, w_fc: float,
                *, seconds: float = 5.0):
    """The paper's algorithm over Sashimi: clients hold conv tickets for
    the measured conv duration; the server consumes returned features and
    performs timed FC work units concurrently."""
    d = Distributor(timeout=30.0, redistribute_min=0.05,
                    project_name="fig5-split")
    counters = {"conv": 0, "fc": 0}
    feature_queue: "queue_mod.Queue" = queue_mod.Queue()
    stop = threading.Event()

    def client_task(args, static):
        time.sleep(w_conv)               # measured conv fwd/bwd cost
        return args                      # "features" token

    d.register_task(TaskDef("conv", client_task))
    seen: set = set()

    def server_loop():
        have_features = False
        while not stop.is_set():
            done = d.queue.results()
            for tid in [t for t in done if t not in seen]:
                seen.add(tid)
                counters["conv"] += 1
                have_features = True
            if not have_features:
                time.sleep(0.001)
                continue
            # the server is DEVOTED to FC training (paper §4.2.2): it keeps
            # training on the latest received features while awaiting more
            time.sleep(w_fc)             # measured fc train cost
            counters["fc"] += 1

    server = threading.Thread(target=server_loop, daemon=True)
    d.spawn_clients([ClientProfile(name=f"gpu{i}")
                     for i in range(n_clients)])
    server.start()
    t0 = time.perf_counter()
    nb = 0
    while time.perf_counter() - t0 < seconds:
        if d.queue.snapshot()["waiting"] < n_clients * 2:
            d.queue.add("conv", nb)
            nb += 1
        time.sleep(0.001)
    dt = time.perf_counter() - t0
    stop.set()
    d.shutdown()
    server.join(timeout=5)
    return counters["conv"] / dt * 60.0, counters["fc"] / dt * 60.0


def run(*, seconds: float = 5.0, max_clients: int = 4):
    w_conv, w_fc = _measure_unit_costs()
    rows = []
    conv0, fc0 = standalone_speed(w_conv, w_fc)
    rows.append({"mode": "standalone", "clients": 0,
                 "conv_batches_per_min": round(conv0, 1),
                 "fc_batches_per_min": round(fc0, 1),
                 "w_conv_ms": round(w_conv * 1e3, 1),
                 "w_fc_ms": round(w_fc * 1e3, 1)})
    for c in range(1, max_clients + 1):
        conv, fc = split_speed(c, w_conv, w_fc, seconds=seconds)
        rows.append({"mode": "split_concurrent", "clients": c,
                     "conv_batches_per_min": round(conv, 1),
                     "fc_batches_per_min": round(fc, 1)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
