"""Table 2 reproduction: distributed nearest-neighbour classification of an
MNIST stand-in, varying the number of (simulated browser) clients 1..4.

The paper classified 1,000 MNIST test images against 60,000 training images
with Chrome clients.  Correctness of the distributed kNN (results identical
to local) is covered by ``tests/test_system.py``.  This benchmark measures
the *scaling* behaviour of the Sashimi distributor.

HOST NOTE: this container has ONE cpu core, so genuinely parallel client
compute is impossible.  In the default ``simulate_work`` mode the per-ticket
kNN cost is measured once for real, then each client "computes" by holding
the ticket for that measured duration (a timed work unit that overlaps
across threads) — the distributor protocol (ticket queue, task/static
download + caching, result collection) runs for real.  On a multi-core host
pass ``simulate_work=False`` to run the real numpy workload.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.distributor import ClientProfile, Distributor, TaskDef
from repro.data import clustered_images


def _knn_chunk(te, tr, tr_y, lo, hi):
    q = te[lo:hi]
    # BLAS-backed distance computation
    d = (q * q).sum(1)[:, None] - 2.0 * q @ tr.T + (tr * tr).sum(1)[None]
    return tr_y[np.argmin(d, axis=1)].tolist()


def knn_elapsed(n_clients: int, *, n_train: int, n_test: int,
                image_size: int, tickets: int,
                simulate_work: bool = True) -> float:
    train_x, train_y = clustered_images(n_train, image_size=image_size,
                                        channels=1, seed=0)
    test_x, _ = clustered_images(n_test, image_size=image_size, channels=1,
                                 seed=1)
    tr = train_x.reshape(n_train, -1)
    te = test_x.reshape(n_test, -1)
    chunk = max(n_test // tickets, 1)
    bounds = [(i, min(i + chunk, n_test)) for i in range(0, n_test, chunk)]

    unit_cost = 0.0
    if simulate_work:
        costs = []
        for _ in range(3):
            t0 = time.perf_counter()
            _knn_chunk(te, tr, train_y, *bounds[0])
            costs.append(time.perf_counter() - t0)
        unit_cost = min(costs)

    def knn_task(args, static):
        tr_x, tr_y = static["train"]
        if simulate_work:
            time.sleep(unit_cost)       # measured real cost, overlappable
            return []
        return _knn_chunk(te, tr_x, tr_y, *args)

    d = Distributor(timeout=30.0, redistribute_min=0.05,
                    project_name="table2-knn")
    d.static_store["train"] = (tr, train_y)
    d.register_task(TaskDef("knn", knn_task, static_files=("train",)))

    t0 = time.perf_counter()
    d.queue.add_many("knn", bounds)
    # per-roundtrip latency models the paper's browser/network overhead
    d.spawn_clients([ClientProfile(name=f"c{i}", cache_capacity=8,
                                   latency=unit_cost * 0.15)
                     for i in range(n_clients)])
    ok = d.queue.wait_all(timeout=600)
    elapsed = time.perf_counter() - t0
    d.shutdown()
    assert ok
    return elapsed


def run(*, n_train: int = 4000, n_test: int = 256, image_size: int = 16,
        tickets: int = 32, max_clients: int = 4,
        simulate_work: bool = True):
    rows = []
    base = None
    for c in range(1, max_clients + 1):
        e = knn_elapsed(c, n_train=n_train, n_test=n_test,
                        image_size=image_size, tickets=tickets,
                        simulate_work=simulate_work)
        base = base or e
        rows.append({"clients": c, "elapsed_s": round(e, 3),
                     "ratio": round(e / base, 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
