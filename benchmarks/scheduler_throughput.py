"""Distributor v2 scheduler benchmark: adaptive vs fixed-size tickets.

Discrete-event simulation (virtual clock — runs in milliseconds, fully
deterministic) of heterogeneous browser clients pulling lease batches from
the real :class:`repro.core.tickets.TicketQueue`, under three client mixes:

  * ``uniform``  — every client executes 10 work-units/s;
  * ``bimodal``  — half the clients are 8x faster than the other half
                   (the paper's desktop-Chrome vs Nexus-7 situation);
  * ``churn``    — bimodal, plus a third of the clients die mid-task at
                   staggered times (closed tabs).

Each (mix, policy) cell reports **makespan** (virtual seconds until every
ticket has a result) and **idle fraction** (time surviving clients spent
waiting for an eligible ticket, over clients x makespan).  Policies:

  * ``v1-fixed-1`` — one ticket per round-trip (the seed Distributor);
  * ``fixed-8``    — naive batching, same size for every client;
  * ``adaptive``   — Distributor v2: lease sized to the client's EWMA
                     throughput, plus the proactive watchdog that releases
                     a lease once it overruns its ETA 3x.

Usage:
  PYTHONPATH=src python benchmarks/scheduler_throughput.py [--json out.json]
"""
from __future__ import annotations

import argparse
import heapq
import itertools
import json
import sys
import time

sys.path.insert(0, "src")

from repro.core.distributor import AdaptiveSizer, FixedSizer
from repro.core.tickets import TicketQueue

RTT = 0.05            # per-lease round-trip latency (s) — browser to server
N_TICKETS = 400
N_CLIENTS = 8
BASE_RATE = 10.0      # work units / s for a "slow" client


class SimClock:
    """Injectable virtual clock (see docs/ARCHITECTURE.md §Injectable
    clock): the event loop sets ``t``; the queue just reads it."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def client_mix(kind: str):
    """[(name, speed, die_at)] for the requested mix; die_at None = immortal."""
    if kind == "uniform":
        return [(f"c{i}", BASE_RATE, None) for i in range(N_CLIENTS)]
    if kind == "bimodal":
        return [(f"fast{i}", 8 * BASE_RATE, None)
                for i in range(N_CLIENTS // 2)] + \
               [(f"slow{i}", BASE_RATE, None) for i in range(N_CLIENTS // 2)]
    if kind == "churn":
        out = []
        for i in range(N_CLIENTS // 2):
            out.append((f"fast{i}", 8 * BASE_RATE,
                        0.2 + 0.2 * i if i % 3 == 0 else None))
        for i in range(N_CLIENTS // 2):
            out.append((f"slow{i}", BASE_RATE,
                        0.4 + 0.3 * i if i % 3 == 1 else None))
        return out
    raise KeyError(kind)


def simulate(mix: str, sizer, *, watchdog: bool, grace: float = 3.0,
             redistribute_min: float = 10.0, timeout: float = 300.0,
             tracer=None, n_tickets: int = None) -> dict:
    """Run one (mix, policy) cell; returns makespan/idle/redistribution
    metrics.  Event-driven: the heap holds (time, seq, kind, payload) with
    kinds 'wake' (client asks for a lease) and 'done' (lease completes).
    ``tracer`` (a ``repro.obs.Tracer``) records the full ticket/lease
    lifecycle on the virtual clock — same-seed traced runs are
    byte-identical (asserted by ``benchmarks/run.py --only obs``)."""
    clock = SimClock()
    if tracer is not None:
        tracer.clock = clock
    q = TicketQueue(timeout=timeout, redistribute_min=redistribute_min,
                    clock=clock, tracer=tracer)
    q.add_many("work", list(range(n_tickets or N_TICKETS)), work=1.0)

    clients = client_mix(mix)
    alive = {name: True for name, _, _ in clients}
    speed = {name: sp for name, sp, _ in clients}
    die_at = {name: d for name, _, d in clients}
    idle_since: dict[str, float] = {}
    idle_total = 0.0
    seq = itertools.count()
    events: list = []
    for name, _, _ in clients:
        heapq.heappush(events, (0.0, next(seq), "wake", name, None))

    makespan = None
    watch_pending: dict[int, float] = {}   # lease_id -> eta deadline

    while events:
        t, _, kind, name, payload = heapq.heappop(events)
        clock.t = t
        if q.all_done():
            makespan = makespan if makespan is not None else t
            break

        if kind == "wake":
            if not alive[name]:
                continue
            if die_at[name] is not None and t >= die_at[name]:
                alive[name] = False
                continue
            stats = q.stats.get(name)
            n = sizer.lease_size(stats)
            batch = q.lease(name, n)
            if batch is None:
                if name not in idle_since:
                    idle_since[name] = t
                heapq.heappush(events, (t + redistribute_min / 4, next(seq),
                                        "wake", name, None))
                continue
            # ETA from the tickets actually granted, as the scheduler does
            eta = sizer.expected_duration(stats, len(batch.ticket_ids))
            batch.expected_duration = eta
            if watchdog and eta is not None:
                # v2 watchdog, modelled faithfully: EVERY lease is released
                # once it overruns grace*eta (release() is a no-op for
                # leases that completed or whose tickets moved on)
                heapq.heappush(events,
                               (batch.issued_at + grace * max(eta, 1e-3),
                                next(seq), "watchdog", name, batch.lease_id))
            if name in idle_since:
                idle_total += t - idle_since.pop(name)
            duration = RTT + batch.work / speed[name]
            finish = t + duration
            if die_at[name] is not None and finish >= die_at[name]:
                # tab closes mid-lease: results are lost; without a
                # watchdog the tickets only return via the VCT /
                # redistribute_min path — exactly the v1 behaviour
                alive[name] = False
                continue
            heapq.heappush(events, (finish, next(seq), "done", name, batch))
        elif kind == "done":
            batch = payload
            q.submit_batch(batch.lease_id,
                           {tid: tid for tid in batch.ticket_ids}, name)
            if q.all_done():
                makespan = t
                break
            heapq.heappush(events, (t, next(seq), "wake", name, None))
        elif kind == "watchdog":
            q.release(payload, client_failed=True)

    if makespan is None:
        makespan = clock.t
    # close out clients still idle at the end
    for name, since in idle_since.items():
        if alive[name]:
            idle_total += makespan - since
    n_alive_seconds = sum(
        (min(die_at[name], makespan) if die_at[name] is not None
         else makespan) for name, _, _ in clients)
    snap = q.snapshot()
    return {
        "makespan_s": round(makespan, 3),
        "idle_frac": round(idle_total / max(n_alive_seconds, 1e-9), 4),
        "redistributions": snap["redistributions"],
        "lease_releases": snap["lease_releases"],
        "completed": snap["executed"],
    }


POLICIES = {
    "v1-fixed-1": (FixedSizer(1), False),
    "fixed-8": (FixedSizer(8), False),
    "adaptive": (AdaptiveSizer(target_lease_time=0.5, max_size=32), True),
}


def overhead_gate(reps: int = 6, n_tickets: int = 8000,
                  budget: float = 1.05, attempts: int = 3) -> dict:
    """Tracing-overhead gate: the sweep cell that stresses the queue
    hardest (bimodal/adaptive) must run within ``budget``x of its
    untraced wall time when every ticket and lease is being traced.

    Measured at ``n_tickets`` (a production-scale backlog, ~20x the
    sweep default) so the comparison reflects real queue work per traced
    event: recording a span is O(1) Python-dict work, while granting a
    lease scans eligible tickets — at toy backlogs the fixed per-event
    cost dominates and the ratio says nothing about deployment overhead.
    Traced/untraced reps are interleaved and both sides take the min
    (noise on a shared box is one-sided — stalls only ever slow a rep
    down), with the cyclic GC parked so a collection landing in one
    side's reps can't bias the ratio.  A measurement over budget
    re-runs, up to ``attempts`` total: sustained noise bursts slip past
    the per-rep min, but they pass, while a real hot-path regression
    fails every attempt."""
    import gc

    from repro.obs import Tracer
    sizer, watchdog = POLICIES["adaptive"]

    def one(traced: bool) -> float:
        t0 = time.perf_counter()
        simulate("bimodal", sizer, watchdog=watchdog, n_tickets=n_tickets,
                 tracer=Tracer() if traced else None)
        return time.perf_counter() - t0

    def measure() -> tuple:
        one(False)                         # warm-up rep, discarded
        gc_was_enabled = gc.isenabled()
        gc.disable()
        try:
            untraced = one(False)
            traced = one(True)
            for _ in range(reps - 1):      # interleaved u/t pairs
                untraced = min(untraced, one(False))
                traced = min(traced, one(True))
                gc.collect()               # pay collection between pairs
        finally:
            if gc_was_enabled:
                gc.enable()
        return untraced, traced

    for _ in range(attempts):
        untraced, traced = measure()
        ratio = traced / untraced
        if ratio <= budget:
            break
    return {"untraced_s": round(untraced, 5), "traced_s": round(traced, 5),
            "n_tickets": n_tickets,
            "ratio": round(ratio, 4), "budget": budget,
            "ok": ratio <= budget}


def run_sweep() -> dict:
    out: dict = {}
    for mix in ("uniform", "bimodal", "churn"):
        out[mix] = {}
        for pname, (sizer, watchdog) in POLICIES.items():
            out[mix][pname] = simulate(mix, sizer, watchdog=watchdog)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    ap.add_argument("--overhead-gate", action="store_true",
                    help="measure tracing overhead on the bimodal/adaptive "
                         "cell and fail unless traced <= 1.05x untraced")
    args = ap.parse_args()
    if args.overhead_gate:
        g = overhead_gate()
        print(f"tracing overhead: traced {g['traced_s']:.4f}s vs untraced "
              f"{g['untraced_s']:.4f}s -> {g['ratio']:.3f}x "
              f"(budget {g['budget']}x)")
        if not g["ok"]:
            sys.exit(f"tracing overhead {g['ratio']:.3f}x exceeds "
                     f"{g['budget']}x budget")
        return
    results = run_sweep()
    hdr = f"{'mix':<10}{'policy':<12}{'makespan(s)':>12}{'idle':>8}" \
          f"{'redist':>8}{'released':>10}"
    print(hdr)
    print("-" * len(hdr))
    for mix, cells in results.items():
        for pname, m in cells.items():
            print(f"{mix:<10}{pname:<12}{m['makespan_s']:>12.2f}"
                  f"{m['idle_frac']:>8.3f}{m['redistributions']:>8}"
                  f"{m['lease_releases']:>10}")
    bi = results["bimodal"]
    speedup = bi["v1-fixed-1"]["makespan_s"] / bi["adaptive"]["makespan_s"]
    print(f"\nbimodal: adaptive is {speedup:.2f}x faster than v1-fixed-1 "
          f"({bi['adaptive']['makespan_s']:.2f}s vs "
          f"{bi['v1-fixed-1']['makespan_s']:.2f}s)")
    assert bi["adaptive"]["makespan_s"] < bi["v1-fixed-1"]["makespan_s"], \
        "adaptive sizing must beat fixed-size tickets on the bimodal mix"
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
