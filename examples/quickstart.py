"""Quickstart: train the paper's Figure-2 deep CNN ("Sukiyaki") with the
modified AdaGrad on a CIFAR-like synthetic set, then save/reload the model
in the paper's JSON+base64 format.

  PYTHONPATH=src python examples/quickstart.py [--batches 100]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_json_model, save_json_model
from repro.configs.paper_cnn import FIG2_CNN
from repro.data import clustered_images
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=100)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--beta", type=float, default=1.0,
                    help="the paper's AdaGrad β")
    ap.add_argument("--out", default="/tmp/sukiyaki_model.json")
    args = ap.parse_args()

    ccfg = FIG2_CNN
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    opt = adagrad(args.lr, beta=args.beta)
    opt_state = opt.init(params)
    images, labels = clustered_images(4096, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=0)
    test_x, test_y = clustered_images(512, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=9)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            return cnn.nll_loss(cnn.forward(p, ccfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    bs = ccfg.batch_size
    t0 = time.time()
    for i in range(args.batches):
        j = (i * bs) % (len(images) - bs)
        params, opt_state, loss = step(
            params, opt_state, jnp.asarray(images[j:j + bs]),
            jnp.asarray(labels[j:j + bs]))
        if i % 20 == 0 or i == args.batches - 1:
            err = float(cnn.error_rate(
                cnn.forward(params, ccfg, jnp.asarray(test_x)),
                jnp.asarray(test_y)))
            print(f"batch {i:4d} loss {float(loss):.4f} "
                  f"test_err {err:.3f}", flush=True)
    dt = time.time() - t0
    print(f"trained {args.batches} batches in {dt:.1f}s "
          f"({args.batches/dt*60:.1f} batches/min)")

    save_json_model(args.out, params)
    rt = load_json_model(args.out)
    assert np.array_equal(np.asarray(params["convs"][0]["w"]),
                          rt["convs"][0]["w"])
    print(f"model saved (paper JSON+base64 format, bit-exact): {args.out}")


if __name__ == "__main__":
    main()
