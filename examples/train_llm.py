"""End-to-end LLM training driver: a ~100M-parameter qwen-family model
trained for a few hundred steps on the synthetic Markov stream with the
paper's split_concurrent strategy and modified AdaGrad.

Defaults are sized for this CPU container (a ~20M model, 200 steps); pass
--d-model 768 --layers 12 --steps 300 for the full ~100M run on real
hardware.

  PYTHONPATH=src python examples/train_llm.py --steps 200
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.split_parallel import init_prev_features, make_train_step
from repro.data import make_lm_batch
from repro.models.model import build_model, count_params_analytic
from repro.optim import get_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--strategy", default="split_concurrent")
    ap.add_argument("--optimizer", default="adagrad")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-4b"),
        name="qwen3-mini",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1),
        head_dim=64, d_ff=args.d_model * 4, vocab_size=args.vocab,
        tie_embeddings=False)
    n_params = count_params_analytic(cfg)
    print(f"model: {cfg.name} {cfg.num_layers}L d={cfg.d_model} "
          f"({n_params/1e6:.1f}M params), strategy={args.strategy}")

    api = build_model(cfg, compute_dtype=jnp.float32)
    opt = get_optimizer(args.optimizer, args.lr, adagrad_beta=1.0)
    init_state, step = make_train_step(api, opt, strategy=args.strategy)
    state = init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def batch():
        return {k: jnp.asarray(v) for k, v in make_lm_batch(
            rng, args.batch, args.seq, cfg.vocab_size).items()}

    first = batch()
    if args.strategy in ("split_concurrent", "split_server_sharded"):
        state = init_prev_features(state, api, first, dtype=jnp.float32)
    jstep = jax.jit(step, donate_argnums=(0,))

    losses = []
    t0 = time.time()
    for i in range(args.steps):
        b = first if i == 0 else batch()
        state, m = jstep(state, b)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq / dt
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({tok_s:,.0f} tok/s)", flush=True)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(noise floor ~{np.log(1/0.9):.2f} for 10% flip noise)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
