"""Sashimi demo: the paper's PrimeListMakerProject (Appendix) plus a
distributed kNN job and a §4.1 split-training round, with simulated
browsers — including a flaky one that crashes and a tab that closes
mid-job, to show ticket redistribution.

Demo 1 runs on the v1 thread-per-client Distributor exactly as in the
paper; demos 2 and 3 run on Distributor v2 (asyncio, adaptively sized
lease batches) with a bimodal fast/slow client mix.

  PYTHONPATH=src python examples/sashimi_browser_sim.py
"""
import asyncio
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, Distributor, TaskDef)
from repro.core.project import CalculationFramework, ProjectBase, TaskBase
from repro.core.split_parallel import SplitConcurrentDispatcher
from repro.data import clustered_images


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    i = 2
    while i * i <= n:
        if n % i == 0:
            return False
        i += 1
    return True


class IsPrimeTask(TaskBase):
    static_code_files = ("is_prime",)

    def run(self, input, static):  # noqa: A002
        return {"is_prime": static["is_prime"](input["candidate"])}


class PrimeListMakerProject(ProjectBase):
    """The paper's appendix example, 1..10000."""

    name = "PrimeListMakerProject"

    def run(self):
        task = self.create_task(IsPrimeTask)
        task.calculate([{"candidate": i} for i in range(1, 10001)])
        results = task.block(timeout=120)
        primes = [i + 1 for i, r in enumerate(results) if r["is_prime"]]
        return primes


def demo_primes_v1():
    """The paper's appendix example on the v1 thread simulator."""
    d = Distributor(timeout=5.0, redistribute_min=0.05)
    fw = CalculationFramework(d)
    fw.add_static("is_prime", is_prime)
    d.spawn_clients([
        ClientProfile(name="chrome-desktop"),
        ClientProfile(name="nexus7-tablet", latency=0.0005),
        ClientProfile(name="flaky-browser", fail_prob=0.05),
        ClientProfile(name="closed-tab", die_after=40),
    ])
    primes = fw.run_project(PrimeListMakerProject)
    console = d.console()
    d.shutdown()
    print(f"{len(primes)} primes found up to 10000 "
          f"(first: {primes[:8]} ... last: {primes[-3:]})")
    print(f"console: executed={console['executed']} "
          f"errors={console['errors']} "
          f"redistributions={console['redistributions']}")
    print(f"clients: {[(c['name'], c['executed']) for c in console['clients']]}")
    assert len(primes) == 1229  # π(10000)


async def demo_knn_v2():
    """Distributed kNN (Table-2 workload) on Distributor v2: a bimodal
    client mix, leases sized to each client's measured throughput."""
    train_x, train_y = clustered_images(2000, image_size=12, channels=1,
                                       seed=0)
    test_x, test_y = clustered_images(200, image_size=12, channels=1, seed=1)
    tr = train_x.reshape(len(train_x), -1)
    te = test_x.reshape(len(test_x), -1)

    def knn(args, static):
        lo, hi = args
        trx, try_ = static["train"]
        q = te[lo:hi]
        dist = ((q[:, None] - trx[None]) ** 2).sum(-1)
        return try_[np.argmin(dist, 1)].tolist()

    d = AsyncDistributor(timeout=10.0, redistribute_min=0.02,
                         sizer=AdaptiveSizer(target_lease_time=0.05,
                                             max_size=16),
                         watchdog_interval=0.01,
                         project_name="DistributedKnn")
    d.add_static("train", (tr, train_y))
    d.register_task(TaskDef("knn", knn, static_files=("train",)))
    tids = d.add_work("knn", [(i, i + 10) for i in range(0, len(te), 10)])
    d.spawn_clients(
        [ClientProfile(name=f"fast{i}", speed=400.0) for i in range(2)] +
        [ClientProfile(name=f"slow{i}", speed=50.0) for i in range(2)])
    assert await d.run_until_done(timeout=120)
    res = d.queue.results()
    pred = np.concatenate([res[t] for t in tids])
    acc = (pred == test_y).mean()
    snap = d.console()
    rates = {n: round(s["rate"] or 0.0, 1)
             for n, s in snap["clients"].items()}
    print(f"distributed kNN accuracy: {acc:.3f} "
          f"({snap['executed']} tickets, v2 adaptive leases)")
    print(f"measured client rates (work/s): {rates}")


async def demo_split_round_v2():
    """One §4.1 split-concurrent round: backbone shard 'gradients' are
    computed by browser clients via the scheduler; the head would update
    server-side concurrently (here: the weighted aggregate)."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(64, 8)).astype(np.float32)

    def backbone_shard(args, static):
        lo, hi = args["lo"], args["hi"]
        # stand-in for the backbone grad: per-shard mean feature
        return {"grad": data[lo:hi].mean(axis=0), "n": hi - lo}

    d = AsyncDistributor(timeout=10.0, redistribute_min=0.02,
                         sizer=AdaptiveSizer(target_lease_time=0.05),
                         watchdog_interval=0.01,
                         project_name="SplitConcurrentRound")
    d.register_task(TaskDef("backbone_shard", backbone_shard))
    d.spawn_clients([ClientProfile(name="fast", speed=400.0),
                     ClientProfile(name="slow", speed=80.0)])
    disp = SplitConcurrentDispatcher(d)
    shards = [{"lo": i, "hi": i + 8} for i in range(0, 64, 8)]
    outs = await disp.run_round(shards, shard_work=[8.0] * len(shards),
                                timeout=60.0)
    agg = SplitConcurrentDispatcher.aggregate(
        [{"grad": o["grad"]} for o in outs], [o["n"] for o in outs])
    await d.shutdown()
    direct = data.mean(axis=0)
    err = float(np.abs(agg["grad"] - direct).max())
    assert err < 1e-5, err
    print(f"split-concurrent round: {len(outs)} backbone shards via "
          f"scheduler, weighted aggregate matches direct mean "
          f"(max err {err:.2e})")


def main():
    demo_primes_v1()
    asyncio.run(demo_knn_v2())
    asyncio.run(demo_split_round_v2())


if __name__ == "__main__":
    main()
