"""Sashimi demo: the paper's PrimeListMakerProject (Appendix) plus a
distributed kNN job, with simulated browsers — including a flaky one that
crashes and a tab that closes mid-job, to show ticket redistribution.

  PYTHONPATH=src python examples/sashimi_browser_sim.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.distributor import ClientProfile, Distributor, TaskDef
from repro.core.project import CalculationFramework, ProjectBase, TaskBase
from repro.data import clustered_images


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    i = 2
    while i * i <= n:
        if n % i == 0:
            return False
        i += 1
    return True


class IsPrimeTask(TaskBase):
    static_code_files = ("is_prime",)

    def run(self, input, static):  # noqa: A002
        return {"is_prime": static["is_prime"](input["candidate"])}


class PrimeListMakerProject(ProjectBase):
    """The paper's appendix example, 1..10000."""

    name = "PrimeListMakerProject"

    def run(self):
        task = self.create_task(IsPrimeTask)
        task.calculate([{"candidate": i} for i in range(1, 10001)])
        results = task.block(timeout=120)
        primes = [i + 1 for i, r in enumerate(results) if r["is_prime"]]
        return primes


def main():
    # --- prime list, as in the paper -------------------------------------
    d = Distributor(timeout=5.0, redistribute_min=0.05)
    fw = CalculationFramework(d)
    fw.add_static("is_prime", is_prime)
    d.spawn_clients([
        ClientProfile(name="chrome-desktop"),
        ClientProfile(name="nexus7-tablet", latency=0.0005),
        ClientProfile(name="flaky-browser", fail_prob=0.05),
        ClientProfile(name="closed-tab", die_after=40),
    ])
    primes = fw.run_project(PrimeListMakerProject)
    console = d.console()
    d.shutdown()
    print(f"{len(primes)} primes found up to 10000 "
          f"(first: {primes[:8]} ... last: {primes[-3:]})")
    print(f"console: executed={console['executed']} "
          f"errors={console['errors']} "
          f"redistributions={console['redistributions']}")
    print(f"clients: {[(c['name'], c['executed']) for c in console['clients']]}")
    assert len(primes) == 1229  # π(10000)

    # --- distributed kNN (Table-2 workload) ------------------------------
    train_x, train_y = clustered_images(2000, image_size=12, channels=1,
                                        seed=0)
    test_x, test_y = clustered_images(200, image_size=12, channels=1, seed=1)
    tr = train_x.reshape(len(train_x), -1)
    te = test_x.reshape(len(test_x), -1)

    def knn(args, static):
        lo, hi = args
        trx, try_ = static["train"]
        q = te[lo:hi]
        dist = ((q[:, None] - trx[None]) ** 2).sum(-1)
        return try_[np.argmin(dist, 1)].tolist()

    d2 = Distributor(timeout=10.0, redistribute_min=0.05)
    fw2 = CalculationFramework(d2)
    fw2.add_static("train", (tr, train_y))
    d2.register_task(TaskDef("knn", knn, static_files=("train",)))
    tids = d2.queue.add_many("knn", [(i, i + 20)
                                     for i in range(0, len(te), 20)])
    d2.spawn_clients([ClientProfile(name=f"browser{i}") for i in range(4)])
    assert d2.queue.wait_all(timeout=120)
    res = d2.queue.results()
    pred = np.concatenate([res[t] for t in tids])
    acc = (pred == test_y).mean()
    d2.shutdown()
    print(f"distributed kNN accuracy: {acc:.3f} "
          f"({d2.console()['executed']} tickets)")


if __name__ == "__main__":
    main()
