"""Sashimi demo: the paper's PrimeListMakerProject (Appendix) plus a
distributed kNN job and a §4.1 split-training round, with simulated
browsers — including a flaky one that crashes and a tab that closes
mid-job, to show ticket redistribution.

Demo 1 runs on the v1 thread-per-client Distributor exactly as in the
paper; demos 2 and 3 run on Distributor v2 (asyncio, adaptively sized
lease batches) with a bimodal fast/slow client mix.

  PYTHONPATH=src python examples/sashimi_browser_sim.py

``--federation`` runs the federation-fabric demo instead: a 3-member
federation over the sharded ticket store serves two task families at
once through per-member edge caches, member 0 is killed mid-run, and
the survivors steal its stranded work (``--all`` runs everything).

  PYTHONPATH=src python examples/sashimi_browser_sim.py --federation

``--transport`` runs the cross-host transport demo: a 2-member
federation behind a ``TransportServer`` loopback socket, every client a
``RemoteBrowserClient`` speaking only the length-prefixed JSON protocol
(docs/PROTOCOL.md) — zero direct object references.  Mid-run every
connection is hard-dropped; the clients reconnect, resume their
unsubmitted results, and the round still completes exactly.

  PYTHONPATH=src python examples/sashimi_browser_sim.py --transport

``--train`` runs the training-fabric demo: round-based data-parallel
SGD over a 3-member federation (``FederatedTrainer`` +
``FederatedTrainingLoop``), shard sizes fed by the fabric's measured
per-client rates (``client_rates`` → ``adaptive_shard_sizes``), a
straggler re-ticketed at the K-of-N barrier, one member killed mid-run
with its home shards rebalanced to survivors, and a round-boundary
checkpoint resumed to the identical loss.

  PYTHONPATH=src python examples/sashimi_browser_sim.py --train

``--trace out.json`` runs the training-fabric demo with the
observability layer on: a ``repro.obs.Tracer`` records the full causal
ticket lifecycle (enqueue -> route -> lease -> execute -> submit ->
barrier), the round timeline, straggler reticketing, and the member
kill + rebalance, then writes a Chrome trace-event JSON you can load
straight into https://ui.perfetto.dev (or chrome://tracing).

  PYTHONPATH=src python examples/sashimi_browser_sim.py --trace out.json
"""
import argparse
import asyncio
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, Distributor, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.project import CalculationFramework, ProjectBase, TaskBase
from repro.core.split_parallel import SplitConcurrentDispatcher
from repro.core.transport import TransportServer, spawn_remote_clients
from repro.data import clustered_images


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    i = 2
    while i * i <= n:
        if n % i == 0:
            return False
        i += 1
    return True


class IsPrimeTask(TaskBase):
    static_code_files = ("is_prime",)

    def run(self, input, static):  # noqa: A002
        return {"is_prime": static["is_prime"](input["candidate"])}


class PrimeListMakerProject(ProjectBase):
    """The paper's appendix example, 1..10000."""

    name = "PrimeListMakerProject"

    def run(self):
        task = self.create_task(IsPrimeTask)
        task.calculate([{"candidate": i} for i in range(1, 10001)])
        results = task.block(timeout=120)
        primes = [i + 1 for i, r in enumerate(results) if r["is_prime"]]
        return primes


def demo_primes_v1():
    """The paper's appendix example on the v1 thread simulator."""
    d = Distributor(timeout=5.0, redistribute_min=0.05)
    fw = CalculationFramework(d)
    fw.add_static("is_prime", is_prime)
    d.spawn_clients([
        ClientProfile(name="chrome-desktop"),
        ClientProfile(name="nexus7-tablet", latency=0.0005),
        ClientProfile(name="flaky-browser", fail_prob=0.05),
        ClientProfile(name="closed-tab", die_after=40),
    ])
    primes = fw.run_project(PrimeListMakerProject)
    console = d.console()
    d.shutdown()
    print(f"{len(primes)} primes found up to 10000 "
          f"(first: {primes[:8]} ... last: {primes[-3:]})")
    print(f"console: executed={console['executed']} "
          f"errors={console['errors']} "
          f"redistributions={console['redistributions']}")
    print(f"clients: {[(c['name'], c['executed']) for c in console['clients']]}")
    assert len(primes) == 1229  # π(10000)


async def demo_knn_v2():
    """Distributed kNN (Table-2 workload) on Distributor v2: a bimodal
    client mix, leases sized to each client's measured throughput."""
    train_x, train_y = clustered_images(2000, image_size=12, channels=1,
                                       seed=0)
    test_x, test_y = clustered_images(200, image_size=12, channels=1, seed=1)
    tr = train_x.reshape(len(train_x), -1)
    te = test_x.reshape(len(test_x), -1)

    def knn(args, static):
        lo, hi = args
        trx, try_ = static["train"]
        q = te[lo:hi]
        dist = ((q[:, None] - trx[None]) ** 2).sum(-1)
        return try_[np.argmin(dist, 1)].tolist()

    d = AsyncDistributor(timeout=10.0, redistribute_min=0.02,
                         sizer=AdaptiveSizer(target_lease_time=0.05,
                                             max_size=16),
                         watchdog_interval=0.01,
                         project_name="DistributedKnn")
    d.add_static("train", (tr, train_y))
    d.register_task(TaskDef("knn", knn, static_files=("train",)))
    tids = d.add_work("knn", [(i, i + 10) for i in range(0, len(te), 10)])
    d.spawn_clients(
        [ClientProfile(name=f"fast{i}", speed=400.0) for i in range(2)] +
        [ClientProfile(name=f"slow{i}", speed=50.0) for i in range(2)])
    assert await d.run_until_done(timeout=120)
    res = d.queue.results()
    pred = np.concatenate([res[t] for t in tids])
    acc = (pred == test_y).mean()
    snap = d.console()
    rates = {n: round(s["rate"] or 0.0, 1)
             for n, s in snap["clients"].items()}
    print(f"distributed kNN accuracy: {acc:.3f} "
          f"({snap['executed']} tickets, v2 adaptive leases)")
    print(f"measured client rates (work/s): {rates}")


async def demo_split_round_v2():
    """§4.1 split-concurrent rounds: backbone shard 'gradients' are
    computed by browser clients via the scheduler; the head would update
    server-side concurrently (here: the weighted aggregate).  Each round
    re-registers the stale-head weights as a versioned static — clients
    revalidate through their caches, so round t can never run against
    round t-1's weights (and unchanged data costs only a counter bump)."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(64, 8)).astype(np.float32)

    def backbone_shard(args, static):
        lo, hi = args["lo"], args["hi"]
        # stand-in for the backbone grad: per-shard mean feature, shifted
        # by this round's server-pushed weight offset
        return {"grad": data[lo:hi].mean(axis=0) + static["weights"],
                "n": hi - lo}

    d = AsyncDistributor(timeout=10.0, redistribute_min=0.02,
                         sizer=AdaptiveSizer(target_lease_time=0.05),
                         watchdog_interval=0.01,
                         project_name="SplitConcurrentRound")
    d.register_task(TaskDef("backbone_shard", backbone_shard,
                            static_files=("weights",)))
    d.spawn_clients([ClientProfile(name="fast", speed=400.0),
                     ClientProfile(name="slow", speed=80.0)])
    shards = [{"lo": i, "hi": i + 8} for i in range(0, 64, 8)]
    direct = data.mean(axis=0)
    # the dispatcher owns client lifetime: keep_alive between rounds,
    # restored when the context exits
    async with SplitConcurrentDispatcher(d) as disp:
        for rnd in range(3):
            outs = await disp.run_round(shards,
                                        shard_work=[8.0] * len(shards),
                                        statics={"weights": float(rnd)},
                                        timeout=60.0)
            agg = SplitConcurrentDispatcher.aggregate(
                [{"grad": o["grad"]} for o in outs], [o["n"] for o in outs])
            err = float(np.abs(agg["grad"] - (direct + rnd)).max())
            assert err < 1e-5, (rnd, err)
    await d.shutdown()
    reval = d.revalidation_count["task:backbone_shard"]
    print(f"split-concurrent: 3 rounds x {len(outs)} backbone shards via "
          f"scheduler, per-round weight re-registration picked up by every "
          f"client (max err {err:.2e}); weights downloaded "
          f"{d.download_count['weights']}x, unchanged task code "
          f"revalidated {reval}x")


async def demo_federation():
    """The federation fabric: 3 member distributors share one sharded
    ticket store (per-task shards, global VCT merge), a bimodal client
    mix is routed least-loaded, task code and datasets are served through
    per-member edge caches, and member 0 is killed mid-run — survivors'
    watchdogs release its stranded leases and steal the work."""
    fed = FederatedDistributor(
        3, n_shards=6, timeout=10.0, redistribute_min=0.5,
        sizer=AdaptiveSizer(target_lease_time=0.05, max_size=16),
        watchdog_interval=0.01, grace=2.0,
        project_name="FederationDemo")

    fed.add_static("is_prime", is_prime)
    fed.register_task(TaskDef(
        "prime", lambda n, s: s["is_prime"](n), static_files=("is_prime",)))
    fed.register_task(TaskDef("square", lambda x, _: x * x))
    prime_tids = fed.add_work("prime", list(range(2, 402)))
    square_tids = fed.add_work("square", list(range(200)))

    fed.spawn_clients(
        [ClientProfile(name=f"fast{i}", speed=4000.0) for i in range(3)] +
        [ClientProfile(name=f"slow{i}", speed=500.0) for i in range(3)])

    await asyncio.sleep(0.02)            # let leases get in flight
    downed = await fed.kill_member(0)
    ok = await fed.run_until_done(timeout=60.0)
    assert ok, fed.console()

    res = fed.queue.results()
    primes = [n for n, tid in zip(range(2, 402), prime_tids) if res[tid]]
    assert len(primes) == 79             # π(401)
    assert all(res[t] == i * i for i, t in enumerate(square_tids))

    con = fed.console()
    print(f"federation: {con['executed']} tickets across 2 task families, "
          f"{fed.queue.n_shards} shards, 3 members "
          f"(member0 killed mid-run, {downed} clients lost)")
    for m in con["members"]:
        e = m["edge"]
        print(f"  {m['name']}: alive={m['alive']} steals={m['steals']} "
              f"edge hit-rate={e['hit_rate']:.2f} "
              f"({e['hits']}/{e['requests']} requests served locally)")
    print(f"  origin egress: {dict(fed.download_count)} "
          f"(misses only — edges absorb the rest)")
    print(f"  lease releases (watchdog rescues): {con['lease_releases']}")


def prime_check(n, static):
    """Module-level so the task code pickles across the wire."""
    return static["is_prime"](n)


async def demo_transport():
    """Cross-host transport: a 2-member federation behind a loopback
    ``TransportServer``, every client a ``RemoteBrowserClient`` that holds
    no reference to any distributor object — leases, submits, asset
    fetches, and invalidations are all framed JSON round-trips
    (docs/PROTOCOL.md).  Mid-run the server hard-drops every connection;
    clients reconnect with resume and the round completes exactly."""
    fed = FederatedDistributor(
        2, n_shards=4, timeout=10.0, redistribute_min=0.05,
        sizer=AdaptiveSizer(target_lease_time=0.05, max_size=16),
        watchdog_interval=0.01, grace=2.0,
        project_name="TransportDemo")
    fed.add_static("is_prime", is_prime)
    fed.register_task(TaskDef("prime", prime_check,
                              static_files=("is_prime",)))
    prime_tids = fed.add_work("prime", list(range(2, 402)))

    server = TransportServer(fed)
    host, port = await server.start()
    clients, tasks = spawn_remote_clients(
        (host, port),
        [ClientProfile(name=f"remote{i}", speed=2000.0) for i in range(4)],
        reconnect_delay=0.02)

    await asyncio.sleep(0.05)            # let leases get in flight
    dropped = server.drop_connections()  # simulated network partition
    ok = await fed.run_until_done(timeout=60.0)
    assert ok, fed.console()
    await asyncio.gather(*tasks)
    wire = server.stats()
    await server.stop()

    res = fed.queue.results()
    primes = [n for n, tid in zip(range(2, 402), prime_tids) if res[tid]]
    assert len(primes) == 79             # π(401)

    print(f"transport: {len(prime_tids)} tickets over {host}:{port}, "
          f"{dropped} connections dropped mid-run, "
          f"{sum(c.reconnects for c in clients)} reconnects — "
          f"all results exact")
    print(f"  wire: {wire['frames_in']}+{wire['frames_out']} frames, "
          f"{wire['bytes_in'] + wire['bytes_out']} bytes, "
          f"{wire['protocol_errors']} protocol errors")
    for c in clients:
        print(f"  {c.profile.name}: member={c.member} "
              f"executed={c.executed} revalidations={c.revalidations} "
              f"reconnects={c.reconnects}")
    print(f"  edges: "
          f"{[round(m.edge.stats()['hit_rate'], 2) for m in fed.members]} "
          f"hit rate; origin egress {dict(fed.download_count)}")


def training_grad_shard(args, static):
    """Module-level gradient task (pickles across the wire): exact
    linear-regression gradient of one row slice of the demo dataset,
    echoing the served weights' round tag (stale-weight detector)."""
    lo, hi = args
    X, y = static["train_data"]
    w = np.asarray(static["weights"]["params"]["w"])
    r = X[lo:hi] @ w - y[lo:hi]
    return {"grad": {"w": (2.0 * X[lo:hi].T @ r / (hi - lo))
                     .astype(np.float32)},
            "loss": float((r ** 2).mean()),
            "round": static["weights"]["round"]}


async def demo_training(checkpoint_dir, trace_path=None):
    """Training fabric: §4.1 data-parallel rounds as a first-class
    federation workload — measured-rate shard sizing, straggler-aware
    K-of-N barrier, mid-run member death with shard rebalancing, and a
    bit-exact round-boundary checkpoint resume.  With ``trace_path``,
    the whole first run is recorded by a ``repro.obs.Tracer`` and
    written out as Perfetto-loadable Chrome trace-event JSON."""
    from repro.core.split_parallel import TrainState, adaptive_shard_sizes
    from repro.obs import MetricsRegistry, Tracer, collect_fabric
    from repro.optim import adagrad
    from repro.train_fabric import (FederatedTrainer, FederatedTrainingLoop,
                                    FusedServerStep, Rebalancer,
                                    checkpoint_path, load_round_checkpoint)

    rng = np.random.default_rng(3)
    X = rng.normal(size=(96, 6)).astype(np.float32)
    w_true = rng.normal(size=(6,)).astype(np.float32)
    y = (X @ w_true).astype(np.float32)
    lr = 0.3
    opt = adagrad(lr)

    async def run(rounds, resume_from=None, kill_at=None, tracer=None,
                  metrics=None):
        from repro.core.distributor import FixedSizer
        fed = FederatedDistributor(
            3, n_shards=6, timeout=20.0, redistribute_min=0.02,
            # one-ticket leases: every client (straggler included) holds
            # exactly one rate-sized shard per round
            sizer=FixedSizer(1),
            watchdog_interval=0.01, grace=2.0,
            project_name="TrainingFabricDemo", tracer=tracer)
        if tracer is not None:
            tracer.clock = fed.queue.clock
        fed.add_static("train_data", (X, y))
        fed.register_task(TaskDef("grad_shard", training_grad_shard,
                                  static_files=("weights", "train_data")))
        fed.spawn_clients(
            [ClientProfile(name=f"fast{i}", speed=2000.0) for i in range(4)]
            + [ClientProfile(name="straggler", speed=40.0)])
        if resume_from is None:
            params = {"w": np.zeros(6, np.float32)}
            state = TrainState(params=params, head={}, head_stale={},
                               opt_state=opt.init(params), head_opt_state={},
                               prev_features=(), prev_labels=(),
                               prev_mask=(), step=np.zeros((), np.int32))
            start = 0
        else:
            state, start, _ = load_round_checkpoint(resume_from)
        trainer = FederatedTrainer(
            fed, task_name="grad_shard", barrier_k=0.8,
            straggler_policy="reticket", timeout=30.0,
            rebalancer=Rebalancer(fed, steal_threshold=3, cooldown=1,
                                  metrics=metrics),
            metrics=metrics)
        loop = FederatedTrainingLoop(
            trainer, opt, state, round_index=start,
            checkpoint_dir=checkpoint_dir,
            # the fused server step: clip + weighted mean + modified
            # AdaGrad in one pass (bit-equal to the tree_map reference)
            server_step=FusedServerStep(opt, lr=lr))
        shard_plans = []
        async with trainer:
            for _ in range(start, rounds):
                if kill_at is not None and loop.round_index == kill_at:
                    await fed.kill_member(0)
                # measured per-client EWMA rates size the round's shards:
                # the straggler's slice shrinks to its throughput, so the
                # barrier stays quiet once the fabric has measured it
                rates = {c: r for c, r in fed.client_rates().items() if r}
                if rates:
                    sizes = [s for s in
                             adaptive_shard_sizes(rates, 96).values()
                             if s > 0]
                else:
                    sizes = [12] * 8       # unmeasured: equal slices
                bounds = np.cumsum([0] + sizes)
                args = [(int(a), int(b))
                        for a, b in zip(bounds[:-1], bounds[1:])]
                shard_plans.append(sizes)
                await loop.run_round(args, [float(s) for s in sizes])
            await trainer.aclose(shutdown=True)
        return loop, fed, trainer, shard_plans

    tracer = Tracer() if trace_path is not None else None
    metrics = MetricsRegistry() if trace_path is not None else None
    loop, fed, trainer, plans = await run(6, kill_at=2, tracer=tracer,
                                          metrics=metrics)
    assert loop.stale_executions == 0
    assert loop.losses[-1] < loop.losses[0]
    con = fed.console()
    print(f"training fabric: {loop.round_index} rounds, loss "
          f"{loop.losses[0]:.4f} -> {loop.losses[-1]:.4f}, "
          f"{loop.stale_executions} stale-weight executions")
    print(f"  straggler re-ticketed {trainer.reticketed_total}x at the "
          f"K-of-N barrier; member0 killed at round 2, "
          f"{con['migrations']} home shards rebalanced to survivors")
    rates = {n: round(r or 0.0, 1) for n, r in fed.client_rates().items()}
    print(f"  measured client rates feeding shard sizes (rows/s): {rates}")
    print(f"  shard plan: round 0 (unmeasured) {plans[0]} -> "
          f"round {len(plans) - 1} (rate-sized) {plans[-1]}")

    if trace_path is not None:
        assert tracer.balanced(), tracer.open_spans()
        tracer.write(trace_path)
        collect_fabric(metrics, distributor=fed)
        steals = metrics.get("federation.steals_total").total()
        migs = metrics.get("rebalancer.migrations_total").total()
        print(f"  trace: {tracer.event_count()} events "
              f"({tracer.spans_closed} spans, all balanced) -> {trace_path} "
              f"(open in ui.perfetto.dev)")
        step_h = metrics.get("round.server_step_seconds")
        print(f"  metrics: {len(metrics.names())} series — e.g. "
              f"federation.steals_total={steals:.0f} "
              f"rebalancer.migrations_total={migs:.0f} "
              f"round.barrier_wait_seconds count="
              f"{metrics.get('round.barrier_wait_seconds').count()}")
        print(f"  fused server step: "
              f"{metrics.get('round.model_params_count').value():.0f} "
              f"params updated {step_h.count()}x, "
              f"{1e3 * step_h.sum() / max(step_h.count(), 1):.2f} ms/round "
              f"(round.server_step_seconds)")

    # kill-and-resume: a fresh federation continues from the round-4
    # checkpoint and lands on the identical loss trajectory
    resumed, _, _, _ = await run(
        6, resume_from=checkpoint_path(checkpoint_dir, 4))
    delta = max(abs(a - b)
                for a, b in zip(loop.losses[4:], resumed.losses))
    assert delta < 1e-5, delta   # partitions may differ; the math is exact
    print(f"  resumed from round-4 checkpoint: max |Δloss| vs unkilled "
          f"run = {delta:.1e} (paper JSON+base64 format, bit-exact)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--federation", action="store_true",
                    help="run the federation-fabric demo only")
    ap.add_argument("--transport", action="store_true",
                    help="run the cross-host transport demo only")
    ap.add_argument("--train", action="store_true",
                    help="run the training-fabric demo only")
    ap.add_argument("--trace", metavar="PATH",
                    help="run the training-fabric demo with the tracer on "
                         "and write a Perfetto trace-event JSON to PATH")
    ap.add_argument("--all", action="store_true",
                    help="run every demo including federation + transport")
    args = ap.parse_args()
    if args.trace:
        with tempfile.TemporaryDirectory() as ckdir:
            asyncio.run(demo_training(ckdir, trace_path=args.trace))
        return
    if args.federation:
        asyncio.run(demo_federation())
        return
    if args.transport:
        asyncio.run(demo_transport())
        return
    if args.train:
        with tempfile.TemporaryDirectory() as ckdir:
            asyncio.run(demo_training(ckdir))
        return
    demo_primes_v1()
    asyncio.run(demo_knn_v2())
    asyncio.run(demo_split_round_v2())
    if args.all:
        asyncio.run(demo_federation())
        asyncio.run(demo_transport())
        with tempfile.TemporaryDirectory() as ckdir:
            asyncio.run(demo_training(ckdir))


if __name__ == "__main__":
    main()
