"""Batched serving demo: prefill a batch of prompts, then greedy-decode with
the KV-cache/state path — runs every architecture family (pass --arch).

  PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-1.6b --gen 32
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.serve import generate
from repro.models.model import build_model
from repro.sharding.spec import values_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    api = build_model(cfg, compute_dtype=jnp.float32, remat=False)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    s_text = args.prompt_len - (cfg.num_patches if cfg.family == "vlm"
                                else 0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, s_text)), jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.normal(0, 0.02,
                       (args.batch, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)

    print(f"serving {cfg.name} ({cfg.family}): batch={args.batch} "
          f"prompt={s_text} gen={args.gen}")
    t0 = time.time()
    toks = generate(api, params, prompts, gen=args.gen, extra_inputs=extra)
    dt = time.time() - t0
    print(f"generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s incl. compile)")
    print("sample tokens:", np.asarray(toks[0][:12]))


if __name__ == "__main__":
    main()
