#!/usr/bin/env python
"""Lint metric names registered anywhere under ``src/``.

Every ``registry.counter("...")`` / ``.gauge("...")`` / ``.histogram
("...")`` registration (and the ``reg.counter(f"cache.{field}_total")``
style in collectors) must follow the fabric's naming convention::

    subsystem.noun_unit        e.g.  cache.hits_total
                                     round.barrier_wait_seconds

The authoritative pattern lives in ``repro.obs.metrics.METRIC_NAME_RE``
(and is also enforced at runtime, at registration) — this lint imports
it rather than re-stating it, so the two can't drift.  The lint exists
because runtime enforcement only fires on code paths a test actually
runs; the lint reads the source, so a metric registered on a rare error
path is still checked in CI.

Usage:
  python tools/check_metric_names.py [src_root]    # default: src

Exit status is nonzero if any registration violates the convention;
each is reported as ``file:line: name — reason``.  f-string
registrations are checked with their ``{...}`` placeholders substituted
by a representative token (placeholders may not span the subsystem dot
or the unit suffix).
"""
from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import METRIC_NAME_RE, UNITS  # noqa: E402

# .counter("name" / .gauge('name' / .histogram("name", plus f-string forms
_REG = re.compile(
    r"\.(counter|gauge|histogram)\(\s*(f?)([\"'])([^\"']+)\3")
_PLACEHOLDER = re.compile(r"\{[^{}]*\}")


def check_name(raw: str, is_fstring: bool) -> str | None:
    """None if ``raw`` is a valid metric name, else the reason."""
    name = raw
    if is_fstring:
        # substitute each placeholder with a representative token; a
        # placeholder may not *be* the subsystem or the unit, so "x"
        # keeps the static skeleton checkable
        name = _PLACEHOLDER.sub("x", raw)
    if METRIC_NAME_RE.match(name):
        return None
    if "." not in name:
        return "missing 'subsystem.' prefix"
    tail = name.rsplit("_", 1)[-1]
    if tail not in UNITS:
        return (f"unit suffix {tail!r} not one of {'/'.join(UNITS)}")
    return "does not match subsystem.noun_unit"


def check_file(path: str) -> list[str]:
    problems = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for m in _REG.finditer(line):
                reason = check_name(m.group(4), m.group(2) == "f")
                if reason:
                    problems.append(
                        f"{path}:{lineno}: {m.group(4)} — {reason}")
    return problems


def find_sources(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".py"))
    return sorted(out)


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "src"
    files = find_sources(root)
    problems = []
    registrations = 0
    for path in files:
        with open(path, encoding="utf-8") as f:
            registrations += sum(1 for line in f for _ in _REG.finditer(line))
        problems.extend(check_file(path))
    for p in problems:
        print(p)
    print(f"checked {len(files)} source files, {registrations} metric "
          f"registration(s): {len(problems)} violation(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
