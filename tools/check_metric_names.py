#!/usr/bin/env python
"""Lint metric names registered anywhere under ``src/``, and cross-check
them against the catalog in ``docs/ARCHITECTURE.md``.

Every ``registry.counter("...")`` / ``.gauge("...")`` / ``.histogram
("...")`` registration (and the ``reg.counter(f"cache.{field}_total")``
style in collectors) must follow the fabric's naming convention::

    subsystem.noun_unit        e.g.  cache.hits_total
                                     round.barrier_wait_seconds

The authoritative pattern lives in ``repro.obs.metrics.METRIC_NAME_RE``
(and is also enforced at runtime, at registration) — this lint imports
it rather than re-stating it, so the two can't drift.  The lint exists
because runtime enforcement only fires on code paths a test actually
runs; the lint reads the source, so a metric registered on a rare error
path is still checked in CI.

**Docs drift.**  ARCHITECTURE.md §Observability carries a metric
catalog (the markdown table whose first header cell starts with
``metric``).  This lint parses it — backticked names, ``{a,b,c}`` brace
sets expanded — and cross-checks against the source registrations in
BOTH directions: a metric registered in code but absent from the
catalog fails, and a catalog row naming a metric nothing registers
fails.  f-string registrations (``cache.{field}_total``) match any
catalog name fitting their skeleton.

Usage:
  python tools/check_metric_names.py [src_root] [architecture_md]
  # defaults: src  docs/ARCHITECTURE.md  (resolved from the repo root)

Exit status is nonzero on any violation; each is reported as
``file:line: name — reason`` (or ``docs: name — reason`` for drift).
"""
from __future__ import annotations

import os
import re
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "src"))

from repro.obs.metrics import METRIC_NAME_RE, UNITS  # noqa: E402

# .counter("name" / .gauge('name' / .histogram("name", plus f-string
# forms; \s* spans newlines because we scan whole-file text (the
# prevailing style wraps the name onto the line after the open paren)
_REG = re.compile(
    r"\.(counter|gauge|histogram)\(\s*(f?)([\"'])([^\"']+)\3")
_PLACEHOLDER = re.compile(r"\{[^{}]*\}")
_BACKTICK = re.compile(r"`([^`]+)`")
_BRACE = re.compile(r"\{([^{}]*)\}")


def check_name(raw: str, is_fstring: bool) -> str | None:
    """None if ``raw`` is a valid metric name, else the reason."""
    name = raw
    if is_fstring:
        # substitute each placeholder with a representative token; a
        # placeholder may not *be* the subsystem or the unit, so "x"
        # keeps the static skeleton checkable
        name = _PLACEHOLDER.sub("x", raw)
    if METRIC_NAME_RE.match(name):
        return None
    if "." not in name:
        return "missing 'subsystem.' prefix"
    tail = name.rsplit("_", 1)[-1]
    if tail not in UNITS:
        return (f"unit suffix {tail!r} not one of {'/'.join(UNITS)}")
    return "does not match subsystem.noun_unit"


def find_registrations(path: str) -> list[tuple[int, str, bool]]:
    """All ``(lineno, name, is_fstring)`` registrations in one file."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return [(text.count("\n", 0, m.start()) + 1, m.group(4),
             m.group(2) == "f")
            for m in _REG.finditer(text)]


def find_sources(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".py"))
    return sorted(out)


def expand_braces(token: str) -> list[str]:
    """``a.{x,y}_total`` → ``[a.x_total, a.y_total]`` (recursive)."""
    m = _BRACE.search(token)
    if m is None:
        return [token]
    out: list[str] = []
    for alt in m.group(1).split(","):
        out.extend(expand_braces(
            token[:m.start()] + alt.strip() + token[m.end():]))
    return out


def catalog_names(md_path: str) -> set[str]:
    """Metric names documented in ARCHITECTURE.md's catalog table: the
    markdown table whose first header cell starts with ``metric``.
    Backticked tokens from the first column, brace sets expanded,
    filtered to well-formed metric names (prose like ``(reason)`` or a
    stray span name never sneaks in)."""
    names: set[str] = set()
    collecting = False
    with open(md_path, encoding="utf-8") as f:
        for line in f:
            if not line.startswith("|"):
                collecting = False
                continue
            cells = line.split("|")
            first = cells[1].strip() if len(cells) > 1 else ""
            if first.lower().startswith("metric"):
                collecting = True          # header row itself has no names
                continue
            if not collecting or set(first) <= set("-: "):
                continue                   # separator row / foreign table
            for token in _BACKTICK.findall(first):
                for name in expand_braces(token):
                    if METRIC_NAME_RE.match(name):
                        names.add(name)
    return names


def cross_check(registered: list[tuple[str, bool]],
                documented: set[str]) -> list[str]:
    """Both drift directions, as ``name — reason`` strings."""
    problems = []
    literals = {name for name, is_f in registered if not is_f}
    patterns = {name: re.compile(
                    _PLACEHOLDER.sub("[a-z0-9_]+", name) + r"\Z")
                for name, is_f in registered if is_f}
    for name in sorted(literals - documented):
        problems.append(f"{name} — registered in source but missing "
                        "from the ARCHITECTURE.md metric catalog")
    for raw, pat in sorted(patterns.items()):
        if not any(pat.match(doc) for doc in documented):
            problems.append(f"{raw} — registered in source (f-string) "
                            "but no catalog entry matches it")
    for name in sorted(documented):
        if name in literals or any(p.match(name)
                                   for p in patterns.values()):
            continue
        problems.append(f"{name} — documented in the catalog but "
                        "registered nowhere under src/")
    return problems


def main() -> int:
    repo_root = os.path.dirname(_HERE)
    root = sys.argv[1] if len(sys.argv) > 1 else (
        os.path.join(repo_root, "src")
        if not os.path.isdir("src") else "src")
    md = sys.argv[2] if len(sys.argv) > 2 else os.path.join(
        repo_root, "docs", "ARCHITECTURE.md")
    files = find_sources(root)
    problems = []
    registered: list[tuple[str, bool]] = []
    for path in files:
        for lineno, name, is_f in find_registrations(path):
            registered.append((name, is_f))
            reason = check_name(name, is_f)
            if reason:
                problems.append(f"{path}:{lineno}: {name} — {reason}")
    documented = catalog_names(md)
    drift = cross_check(registered, documented)
    problems.extend(f"docs: {p}" for p in drift)
    for p in problems:
        print(p)
    print(f"checked {len(files)} source files, {len(registered)} metric "
          f"registration(s), {len(documented)} catalog entrie(s): "
          f"{len(problems)} violation(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
