#!/usr/bin/env python
"""Check that intra-repo markdown links resolve.

Scans markdown files for inline links/images ``[text](target)`` and
verifies every *relative* target (skipping http(s)/mailto/absolute URLs)
points at an existing file or directory, resolved against the linking
file's location.  For ``file.md#anchor`` (and in-file ``#anchor``)
targets, the anchor must match a heading in the target file under
GitHub's slug rules (lowercase, punctuation stripped, spaces → dashes).

Usage:
  python tools/check_md_links.py [root]        # default: repo root

Exit status is nonzero if any link is broken; each broken link is
reported as ``file:line: target — reason``.  CI runs this in the docs
job so README ⇄ ARCHITECTURE ⇄ PROTOCOL cross-links can't rot.
"""
from __future__ import annotations

import os
import re
import sys

# inline links/images, tolerating one level of nested [] in the text;
# reference-style definitions are rare here and skipped on purpose
_LINK = re.compile(r"!?\[(?:[^\]\[]|\[[^\]]*\])*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.+?)\s*#*\s*$")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading: lowercase, drop everything but
    word chars/spaces/dashes, spaces to dashes (backticks etc. removed)."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(md_path: str) -> set[str]:
    """All anchor slugs a markdown file exposes (fenced code excluded)."""
    slugs: set[str] = set()
    seen: dict[str, int] = {}
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for line in f:
            if _CODE_FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING.match(line)
            if not m:
                continue
            slug = github_slug(m.group(1))
            n = seen.get(slug, 0)
            seen[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_links(md_path: str):
    """Yield ``(line_number, target)`` for every inline link, skipping
    fenced code blocks (ASCII diagrams are full of ``[...]``)."""
    in_fence = False
    with open(md_path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if _CODE_FENCE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK.finditer(line):
                yield i, m.group(1)


def check_file(md_path: str) -> list[str]:
    """Broken-link report lines for one markdown file (empty = clean)."""
    problems = []
    base = os.path.dirname(md_path)
    for lineno, target in iter_links(md_path):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        if target.startswith("/"):
            continue                                   # site-absolute: skip
        path_part, _, anchor = target.partition("#")
        dest = md_path if not path_part else os.path.normpath(
            os.path.join(base, path_part))
        if not os.path.exists(dest):
            problems.append(f"{md_path}:{lineno}: {target} — "
                            f"no such file {dest}")
            continue
        if anchor and dest.endswith(".md"):
            if anchor not in heading_slugs(dest):
                problems.append(f"{md_path}:{lineno}: {target} — "
                                f"no heading #{anchor} in {dest}")
    return problems


def find_markdown(root: str) -> list[str]:
    """Every tracked-ish .md under root (skips hidden dirs and caches)."""
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    files = find_markdown(root)
    problems = []
    for md in files:
        problems.extend(check_file(md))
    for p in problems:
        print(p)
    print(f"checked {len(files)} markdown files: "
          f"{len(problems)} broken link(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
